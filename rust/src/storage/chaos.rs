//! Chaos/shaping decorators over the substrate traits.
//!
//! The paper's fault-tolerance story (§4.1, Figure 9b) rests on the
//! substrate being *unreliable*: SQS delivers at-least-once, S3 calls
//! fail transiently, Lambdas straggle and die. The plain backends are
//! perfectly reliable and zero-latency, so the recovery path would
//! never be exercised end-to-end without this layer: generic wrappers
//! that compose over **any** [`BlobStore`]/[`Queue`]/[`KvState`]
//! backend and inject seeded, deterministic trouble.
//!
//! What each decorator injects (all off by default):
//!
//! * [`ChaosBlobStore`] — transient get/put/delete failures with
//!   probability `err` (marked with [`TRANSIENT_MARKER`]; see
//!   [`is_transient`]), per-op latency sampled from
//!   `read_lat`/`write_lat` (a `scan_prefix` pays one `read_lat` draw,
//!   a `delete`/`delete_prefix` one `write_lat` draw — bulk ops are
//!   one round-trip, like an S3 lifecycle sweep), and per-worker
//!   straggler slowdowns (`straggle=FRAC:MULT` — a deterministic
//!   `FRAC` of worker ids see `MULT`× the sampled latency; lifecycle
//!   ops carry no worker id and are never straggled). A
//!   `partition=FRAC:DUR` clause adds whole-backend unreachability:
//!   with probability `FRAC` an op opens a `DUR`-long window during
//!   which get/put/delete fail transiently without reaching the
//!   backend at all;
//! * [`ChaosQueue`] — duplicated enqueues with probability `dup`
//!   (at-least-once *send*) and dropped deliveries with probability
//!   `drop`: a dropped delivery takes the lease but never reaches the
//!   caller, so the message sits invisible until the visibility
//!   timeout expires and redelivers it — exactly a delivery lost in
//!   flight on real SQS. Send latency comes from `send_lat` (the
//!   enqueue round-trip the *sender* pays — child propagation and root
//!   seeding slow down, not delivery), receive latency from
//!   `recv_lat`. During a `partition` window receives return empty
//!   *before* any lease is taken — an unreachable endpoint, not a
//!   lost delivery (contrast `drop`, which leases first);
//! * [`ChaosKvState`] — per-op latency from `kv_lat`, covering the
//!   lifecycle ops (`delete`, `scan_prefix`, `delete_prefix`) as well
//!   as the RMW primitives. The trait surface is infallible by design
//!   (the engine's control plane has no retry story for it), so
//!   `kv_err=P` injects *internal* attempt failures instead: each op
//!   fails-and-retries with probability `P` inside the decorator,
//!   absorbed by a bounded loop (≤ 4 attempts, each paying one
//!   `kv_lat` draw) — the DynamoDB-style conditional-write retry made
//!   visible as latency rather than as an error. [`Queue::purge_prefix`]
//!   is a control-plane drain and passes through unshaped.
//!
//! Selection is part of the substrate grammar
//! ([`SubstrateConfig::parse`](crate::config::SubstrateConfig::parse)):
//!
//! ```text
//! --substrate 'sharded:16+chaos(err=0.01,lat=lognorm:5ms)'
//! --substrate 'strict+chaos(drop=0.05,dup=0.05,seed=7)'
//! --substrate 'sharded:8+chaos(lat=uniform:1ms:20ms,straggle=0.1:16)'
//! ```
//!
//! Clause reference (comma-separated `key=value` inside `chaos(…)`):
//!
//! | key        | value                                  | injects                      |
//! |------------|----------------------------------------|------------------------------|
//! | `err`      | probability in [0,1]                   | blob get/put/delete failures |
//! | `drop`     | probability in [0,1]                   | lost deliveries              |
//! | `dup`      | probability in [0,1]                   | duplicate enqueues           |
//! | `lat`      | latency spec (sets read+write)         | blob latency                 |
//! | `read_lat` | latency spec                           | blob get/scan latency        |
//! | `write_lat`| latency spec                           | blob put/delete latency      |
//! | `send_lat` | latency spec                           | queue send latency           |
//! | `recv_lat` | latency spec                           | queue recv latency           |
//! | `kv_lat`   | latency spec                           | KV op latency (incl. delete/scan/delete_prefix) |
//! | `straggle` | `FRAC:MULT`                            | slow workers                 |
//! | `partition`| `FRAC:DUR`                             | unreachability windows       |
//! | `kv_err`   | probability in [0,1]                   | internal KV attempt failures |
//! | `skew`     | signed duration (`50ms`, `-2s`)        | substrate clock offset vs workers' |
//! | `seed`     | u64                                    | the PRNG seed                |
//!
//! Latency specs: a bare duration (`5ms`, `250us`, `0.01s`, plain
//! seconds) means fixed; `fixed:D`, `uniform:LO:HI`, and
//! `lognorm:MEDIAN[:SIGMA]` (sigma defaults to 0.5) select the
//! distribution. `skew=D` (optionally negative: `skew=-50ms`) is not a
//! fault injected by these decorators but a *clock* perturbation: the
//! substrate builder wraps the queue backends' injected
//! [`Clock`](crate::storage::clock::Clock) in a
//! [`SkewClock`](crate::storage::clock::SkewClock) offset by `D`, so
//! lease stamping and expiry run on a timeline shifted relative to the
//! workers' — the cross-machine clock-disagreement scenario of a real
//! S3/SQS deployment. Because a queue reads the *same* skewed handle
//! for both the lease take and the expiry check, a constant skew must
//! not change redelivery behavior; the conformance suite pins that
//! invariance. `straggle` multiplies the *shaped* blob latency, so
//! it requires a `lat`/`read_lat`/`write_lat` clause (rejected at
//! parse time otherwise — a stragglerless straggler experiment would
//! silently measure nothing). Everything is drawn from one seeded xoshiro stream,
//! so a given config replays the same fault/latency sequence for the
//! same serialized operation order.
//!
//! Virtual-time callers (the discrete-event simulator) wrap with
//! `sleep = false`: fault/drop/dup injection still applies, but
//! latency shaping is skipped — the sim's cost model owns time.

use crate::linalg::matrix::Matrix;
use crate::storage::traits::{BlobStore, KvState, Lease, Queue, StoreStats};
use crate::util::prng::Rng;
use anyhow::{anyhow, bail, Context, Result};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Marker embedded in every injected error message. The executor
/// treats marked failures as retryable (and, past the retry budget,
/// abandons the task to lease-expiry recovery) instead of fatal.
pub const TRANSIENT_MARKER: &str = "transient substrate fault";

/// Is this error an injected transient fault (directly or anywhere in
/// its context chain)? The vendored `anyhow` shim has no downcasting,
/// so the marker string carries the classification.
pub fn is_transient(err: &anyhow::Error) -> bool {
    format!("{err:#}").contains(TRANSIENT_MARKER)
}

/// Inline retries a *worker* gives a transiently-failing blob op
/// before abandoning the task to lease-expiry recovery (§4.1): with
/// independent per-op faults, k retries drive the abandon probability
/// to `err^(k+1)`, and the lease path covers the rest.
pub const WORKER_BLOB_RETRIES: usize = 3;

/// Inline retries for *client-side* blob ops (input seeding, output
/// fetch). The client has no lease to fall back on, so its budget is
/// deeper.
pub const CLIENT_BLOB_RETRIES: usize = 8;

/// Run a borrowing blob op with up to `retries` inline retries on
/// transient faults (exponential backoff); non-transient errors
/// propagate immediately.
pub fn with_blob_retry<T>(retries: usize, mut op: impl FnMut() -> Result<T>) -> Result<T> {
    let mut backoff = Duration::from_micros(200);
    for _ in 0..retries {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) if is_transient(&e) => {
                std::thread::sleep(backoff);
                backoff *= 2;
            }
            Err(e) => return Err(e),
        }
    }
    op()
}

/// `BlobStore::put` consumes its tile, so retries need a copy — clone
/// on the retry attempts, move on the last. Callers on a hot path
/// should skip this when no chaos layer is configured (no transient
/// faults exist, and the first attempt clones).
pub fn blob_put_with_retry(
    store: &dyn BlobStore,
    retries: usize,
    worker: usize,
    key: &str,
    tile: Matrix,
) -> Result<()> {
    let mut backoff = Duration::from_micros(200);
    let mut tile = Some(tile);
    for attempt in 0.. {
        let last = attempt >= retries;
        let value = if last {
            tile.take().expect("tile consumed before final attempt")
        } else {
            tile.as_ref().expect("tile present").clone()
        };
        match store.put(worker, key, value) {
            Ok(()) => return Ok(()),
            Err(e) if !last && is_transient(&e) => {
                std::thread::sleep(backoff);
                backoff *= 2;
            }
            Err(e) => return Err(e),
        }
    }
    unreachable!("retry loop always returns")
}

/// A per-operation latency distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LatencyDist {
    /// No shaping.
    Off,
    /// Constant per-op latency.
    Fixed(Duration),
    /// Uniform in `[lo, hi)`.
    Uniform(Duration, Duration),
    /// Log-normal: `median × exp(sigma · N(0,1))` — the classic
    /// heavy-tailed storage-latency shape.
    LogNormal { median: Duration, sigma: f64 },
}

impl LatencyDist {
    pub fn is_off(&self) -> bool {
        matches!(self, LatencyDist::Off)
    }

    /// Draw one latency.
    pub fn sample(&self, rng: &mut Rng) -> Duration {
        match *self {
            LatencyDist::Off => Duration::ZERO,
            LatencyDist::Fixed(d) => d,
            LatencyDist::Uniform(lo, hi) => {
                Duration::from_secs_f64(rng.range_f64(lo.as_secs_f64(), hi.as_secs_f64()))
            }
            LatencyDist::LogNormal { median, sigma } => {
                Duration::from_secs_f64(median.as_secs_f64() * (sigma * rng.normal()).exp())
            }
        }
    }

    /// Parse `D` | `off` | `fixed:D` | `uniform:LO:HI` |
    /// `lognorm:MEDIAN[:SIGMA]` where durations take `ms`/`us`/`s`
    /// suffixes (bare numbers are seconds).
    pub fn parse(spec: &str) -> Result<LatencyDist> {
        let parts: Vec<&str> = spec.split(':').collect();
        match parts.as_slice() {
            ["off"] => Ok(LatencyDist::Off),
            [d] => Ok(LatencyDist::Fixed(parse_duration(d)?)),
            ["fixed", d] => Ok(LatencyDist::Fixed(parse_duration(d)?)),
            ["uniform", lo, hi] => {
                let (lo, hi) = (parse_duration(lo)?, parse_duration(hi)?);
                if hi < lo {
                    bail!("uniform latency bounds out of order in `{spec}`");
                }
                Ok(LatencyDist::Uniform(lo, hi))
            }
            ["lognorm", med] => Ok(LatencyDist::LogNormal {
                median: parse_duration(med)?,
                sigma: 0.5,
            }),
            ["lognorm", med, sig] => {
                let sigma: f64 = sig
                    .parse()
                    .map_err(|_| anyhow!("bad lognorm sigma `{sig}`"))?;
                if !(0.0..=5.0).contains(&sigma) {
                    bail!("lognorm sigma `{sig}` outside [0, 5]");
                }
                Ok(LatencyDist::LogNormal {
                    median: parse_duration(med)?,
                    sigma,
                })
            }
            _ => bail!(
                "bad latency spec `{spec}` (D | off | fixed:D | uniform:LO:HI | \
                 lognorm:MEDIAN[:SIGMA])"
            ),
        }
    }
}

/// Parse `5ms`, `250us`, `1.5s`, or plain (fractional) seconds.
pub fn parse_duration(s: &str) -> Result<Duration> {
    let (num, scale) = if let Some(v) = s.strip_suffix("ms") {
        (v, 1e-3)
    } else if let Some(v) = s.strip_suffix("us") {
        (v, 1e-6)
    } else if let Some(v) = s.strip_suffix('s') {
        (v, 1.0)
    } else {
        (s, 1.0)
    };
    let x: f64 = num
        .trim()
        .parse()
        .map_err(|_| anyhow!("bad duration `{s}`"))?;
    if !x.is_finite() || x < 0.0 {
        bail!("bad duration `{s}`");
    }
    Ok(Duration::from_secs_f64(x * scale))
}

/// The knob set for one chaos layer (see the module docs for the
/// textual grammar).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChaosConfig {
    /// Blob get/put transient-failure probability.
    pub err: f64,
    /// Queue delivery-drop probability (lease taken, delivery lost).
    pub drop: f64,
    /// Queue enqueue-duplication probability.
    pub dup: f64,
    pub read_lat: LatencyDist,
    pub write_lat: LatencyDist,
    pub send_lat: LatencyDist,
    pub recv_lat: LatencyDist,
    pub kv_lat: LatencyDist,
    /// Fraction of worker ids that are stragglers.
    pub straggler_frac: f64,
    /// Latency multiplier a straggler sees on blob ops.
    pub straggler_mult: f64,
    /// Probability that an op opens an unreachability window
    /// (`partition=FRAC:DUR`).
    pub partition_frac: f64,
    /// Wall-clock length of one unreachability window.
    pub partition_dur: Duration,
    /// Per-attempt internal KV failure probability (`kv_err=P`),
    /// absorbed by bounded in-decorator retries.
    pub kv_err: f64,
    /// Signed clock skew (nanoseconds) the substrate's queue clock
    /// runs at relative to the workers' (`skew=D`; applied by
    /// [`Substrate::build_base`](crate::storage::Substrate) via
    /// [`SkewClock`](crate::storage::clock::SkewClock), not by the
    /// decorators in this module).
    pub skew_ns: i64,
    pub seed: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            err: 0.0,
            drop: 0.0,
            dup: 0.0,
            read_lat: LatencyDist::Off,
            write_lat: LatencyDist::Off,
            send_lat: LatencyDist::Off,
            recv_lat: LatencyDist::Off,
            kv_lat: LatencyDist::Off,
            straggler_frac: 0.0,
            straggler_mult: 1.0,
            partition_frac: 0.0,
            partition_dur: Duration::ZERO,
            kv_err: 0.0,
            skew_ns: 0,
            seed: 0x0C1A05,
        }
    }
}

impl ChaosConfig {
    /// Parse the comma-separated `key=value` body of a `chaos(…)`
    /// decorator clause.
    pub fn parse(body: &str) -> Result<ChaosConfig> {
        let prob = |v: &str| -> Result<f64> {
            let p: f64 = v
                .parse()
                .map_err(|_| anyhow!("bad probability `{v}`"))?;
            if !(0.0..=1.0).contains(&p) {
                bail!("probability `{v}` outside [0, 1]");
            }
            Ok(p)
        };
        let mut c = ChaosConfig::default();
        for kv in body.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (k, v) = kv
                .split_once('=')
                .with_context(|| format!("chaos clause `{kv}` is not key=value"))?;
            let (k, v) = (k.trim(), v.trim());
            match k {
                "err" => c.err = prob(v)?,
                "drop" => c.drop = prob(v)?,
                "dup" => c.dup = prob(v)?,
                "lat" => {
                    let d = LatencyDist::parse(v)?;
                    c.read_lat = d;
                    c.write_lat = d;
                }
                "read_lat" => c.read_lat = LatencyDist::parse(v)?,
                "write_lat" => c.write_lat = LatencyDist::parse(v)?,
                "send_lat" => c.send_lat = LatencyDist::parse(v)?,
                "recv_lat" => c.recv_lat = LatencyDist::parse(v)?,
                "kv_lat" => c.kv_lat = LatencyDist::parse(v)?,
                "straggle" => {
                    let (f, m) = v.split_once(':').context("straggle is FRAC:MULT")?;
                    c.straggler_frac = prob(f)?;
                    c.straggler_mult = m
                        .parse()
                        .map_err(|_| anyhow!("bad straggle multiplier `{m}`"))?;
                    if !(c.straggler_mult >= 1.0 && c.straggler_mult.is_finite()) {
                        bail!("straggle multiplier `{m}` must be a finite value >= 1");
                    }
                }
                "partition" => {
                    let (f, d) = v.split_once(':').context("partition is FRAC:DUR")?;
                    c.partition_frac = prob(f)?;
                    c.partition_dur = parse_duration(d)?;
                }
                "kv_err" => c.kv_err = prob(v)?,
                "skew" => {
                    let (sign, mag) = match v.strip_prefix('-') {
                        Some(rest) => (-1i64, rest),
                        None => (1i64, v),
                    };
                    let d = parse_duration(mag)?;
                    let ns: i64 = i64::try_from(d.as_nanos())
                        .map_err(|_| anyhow!("skew `{v}` out of range"))?;
                    c.skew_ns = sign * ns;
                }
                "seed" => c.seed = v.parse().map_err(|_| anyhow!("bad seed `{v}`"))?,
                other => bail!(
                    "unknown chaos key `{other}` \
                     (err|drop|dup|lat|read_lat|write_lat|send_lat|recv_lat|kv_lat|straggle|\
                      partition|kv_err|skew|seed)"
                ),
            }
        }
        // The straggler multiplier scales the *shaped* blob latency; with
        // no latency clause it would be a silent no-op experiment.
        if c.straggler_frac > 0.0 && c.read_lat.is_off() && c.write_lat.is_off() {
            bail!("straggle requires a blob latency clause (lat=…, read_lat=…, or write_lat=…)");
        }
        Ok(c)
    }

    /// Deterministic straggler membership: the same `(seed, worker)`
    /// always lands on the same side, so straggler experiments are
    /// reproducible without coordination.
    pub fn is_straggler(&self, worker: usize) -> bool {
        if self.straggler_frac <= 0.0 {
            return false;
        }
        let key = self.seed ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(key).f64() < self.straggler_frac
    }
}

/// One seeded draw source shared by a decorator's operations. The
/// stream is deterministic for a fixed serialized op order (tests);
/// under true concurrency the interleaving picks which op gets which
/// draw, but the aggregate rates stay exact.
struct Draws {
    rng: Mutex<Rng>,
}

impl Draws {
    fn new(seed: u64) -> Self {
        Draws {
            rng: Mutex::new(Rng::new(seed)),
        }
    }

    fn chance(&self, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        self.rng.lock().unwrap().chance(p)
    }

    fn latency(&self, dist: &LatencyDist) -> Duration {
        if dist.is_off() {
            return Duration::ZERO;
        }
        dist.sample(&mut self.rng.lock().unwrap())
    }
}

fn maybe_sleep(d: Duration) {
    if !d.is_zero() {
        std::thread::sleep(d);
    }
}

/// One decorator's unreachability window (`partition=FRAC:DUR`).
/// An op that draws the trigger opens a wall-clock window; every op
/// landing inside it (including the trigger itself) is blocked before
/// reaching the inner backend. Fault injection, not latency shaping —
/// it applies even to virtual-time callers (`sleep = false`), exactly
/// like `err`/`drop`.
struct Partition {
    frac: f64,
    dur: Duration,
    window: Mutex<Option<Instant>>,
}

impl Partition {
    fn new(cfg: &ChaosConfig) -> Self {
        Partition {
            frac: cfg.partition_frac,
            dur: cfg.partition_dur,
            window: Mutex::new(None),
        }
    }

    /// Is the backend unreachable for this op? Returns the remaining
    /// window length when blocked (so blocking callers can wait it
    /// out instead of spinning).
    fn blocked(&self, draws: &Draws) -> Option<Duration> {
        if self.frac <= 0.0 {
            return None;
        }
        let mut window = self.window.lock().unwrap();
        let now = Instant::now();
        if let Some(until) = *window {
            if now < until {
                return Some(until - now);
            }
            *window = None;
        }
        if draws.chance(self.frac) {
            *window = Some(now + self.dur);
            return Some(self.dur);
        }
        None
    }
}

// ---------------------------------------------------------------- blob

/// Fault/latency decorator over any [`BlobStore`].
pub struct ChaosBlobStore {
    inner: Arc<dyn BlobStore>,
    cfg: ChaosConfig,
    draws: Draws,
    partition: Partition,
    sleep: bool,
}

impl ChaosBlobStore {
    pub fn new(inner: Arc<dyn BlobStore>, cfg: ChaosConfig, sleep: bool) -> Self {
        ChaosBlobStore {
            inner,
            partition: Partition::new(&cfg),
            cfg,
            draws: Draws::new(cfg.seed ^ 0xB10B),
            sleep,
        }
    }

    fn shape(&self, dist: &LatencyDist, worker: usize) {
        if !self.sleep {
            return;
        }
        let mut d = self.draws.latency(dist);
        if !d.is_zero() && self.cfg.is_straggler(worker) {
            d = d.mul_f64(self.cfg.straggler_mult);
        }
        maybe_sleep(d);
    }
}

impl BlobStore for ChaosBlobStore {
    fn put(&self, worker: usize, key: &str, value: Matrix) -> Result<()> {
        if self.partition.blocked(&self.draws).is_some() {
            return Err(anyhow!("{TRANSIENT_MARKER}: backend partitioned, put `{key}`"));
        }
        self.shape(&self.cfg.write_lat, worker);
        if self.draws.chance(self.cfg.err) {
            return Err(anyhow!("{TRANSIENT_MARKER}: injected put failure for `{key}`"));
        }
        self.inner.put(worker, key, value)
    }

    fn get(&self, worker: usize, key: &str) -> Result<Arc<Matrix>> {
        if self.partition.blocked(&self.draws).is_some() {
            return Err(anyhow!("{TRANSIENT_MARKER}: backend partitioned, get `{key}`"));
        }
        self.shape(&self.cfg.read_lat, worker);
        if self.draws.chance(self.cfg.err) {
            return Err(anyhow!("{TRANSIENT_MARKER}: injected get failure for `{key}`"));
        }
        self.inner.get(worker, key)
    }

    fn contains(&self, key: &str) -> bool {
        self.inner.contains(key)
    }

    fn delete(&self, key: &str) -> Result<bool> {
        // Worker-less op: shaped by write_lat (no straggler multiplier),
        // and err-eligible like put — GC callers retry like workers do.
        if self.partition.blocked(&self.draws).is_some() {
            return Err(anyhow!(
                "{TRANSIENT_MARKER}: backend partitioned, delete `{key}`"
            ));
        }
        if self.sleep {
            maybe_sleep(self.draws.latency(&self.cfg.write_lat));
        }
        if self.draws.chance(self.cfg.err) {
            return Err(anyhow!(
                "{TRANSIENT_MARKER}: injected delete failure for `{key}`"
            ));
        }
        self.inner.delete(key)
    }

    fn scan_prefix(&self, prefix: &str) -> Vec<String> {
        // One listing round-trip's worth of read latency; infallible.
        if self.sleep {
            maybe_sleep(self.draws.latency(&self.cfg.read_lat));
        }
        self.inner.scan_prefix(prefix)
    }

    fn delete_prefix(&self, prefix: &str) -> usize {
        // One bulk-delete round-trip's worth of write latency; the
        // lifecycle-sweep analogue is infallible by contract.
        if self.sleep {
            maybe_sleep(self.draws.latency(&self.cfg.write_lat));
        }
        self.inner.delete_prefix(prefix)
    }

    fn prefix_age(&self, prefix: &str) -> Option<Duration> {
        // Control-plane metadata reads (like `len`): pass through
        // unshaped and unfaulted — the TTL sweeper's polling surface.
        self.inner.prefix_age(prefix)
    }

    fn prefix_ages(&self, delimiter: char) -> Vec<(String, Duration)> {
        self.inner.prefix_ages(delimiter)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn stats(&self) -> StoreStats {
        self.inner.stats()
    }

    fn worker_stats(&self, worker: usize) -> StoreStats {
        self.inner.worker_stats(worker)
    }

    fn known_workers(&self) -> Vec<usize> {
        self.inner.known_workers()
    }
}

// --------------------------------------------------------------- queue

/// Drop/duplicate/latency decorator over any [`Queue`].
pub struct ChaosQueue {
    inner: Arc<dyn Queue>,
    cfg: ChaosConfig,
    draws: Draws,
    partition: Partition,
    sleep: bool,
}

impl ChaosQueue {
    pub fn new(inner: Arc<dyn Queue>, cfg: ChaosConfig, sleep: bool) -> Self {
        ChaosQueue {
            inner,
            partition: Partition::new(&cfg),
            cfg,
            draws: Draws::new(cfg.seed ^ 0x05E5),
            sleep,
        }
    }

    /// An unreachable endpoint: the receive returns empty *before*
    /// the inner queue is touched, so no lease is taken (contrast
    /// `drop`, which leases first and loses the delivery). Blocking
    /// callers wait out the shorter of the window and their timeout
    /// instead of spinning.
    fn partitioned(&self, budget: Duration) -> bool {
        match self.partition.blocked(&self.draws) {
            None => false,
            Some(remaining) => {
                if self.sleep {
                    maybe_sleep(remaining.min(budget));
                }
                true
            }
        }
    }

    /// A delivery that never reaches the caller: the inner queue has
    /// already taken the lease, so the message stays invisible until
    /// the visibility timeout expires and redelivers it — the
    /// at-least-once path §4.1 is built to survive.
    fn filter(&self, got: Option<(String, Lease)>) -> Option<(String, Lease)> {
        let got = got?;
        if self.draws.chance(self.cfg.drop) {
            return None;
        }
        Some(got)
    }
}

impl Queue for ChaosQueue {
    fn send(&self, body: &str, priority: i64) {
        if self.sleep {
            maybe_sleep(self.draws.latency(&self.cfg.send_lat));
        }
        self.inner.send(body, priority);
        if self.draws.chance(self.cfg.dup) {
            // At-least-once enqueue made real: execution is idempotent,
            // so a duplicated task costs time, never correctness.
            self.inner.send(body, priority);
        }
    }

    fn send_hinted(&self, body: &str, priority: i64, hint: Option<u64>) {
        // Explicit forward: the trait default would route through
        // `self.send` and silently drop the locality hint. Same
        // shaping as `send` — a duplicated enqueue keeps its hint.
        if self.sleep {
            maybe_sleep(self.draws.latency(&self.cfg.send_lat));
        }
        self.inner.send_hinted(body, priority, hint);
        if self.draws.chance(self.cfg.dup) {
            self.inner.send_hinted(body, priority, hint);
        }
    }

    fn receive(&self) -> Option<(String, Lease)> {
        if self.partitioned(Duration::ZERO) {
            return None;
        }
        if self.sleep {
            maybe_sleep(self.draws.latency(&self.cfg.recv_lat));
        }
        self.filter(self.inner.receive())
    }

    fn receive_for(&self, worker: u64) -> Option<(String, Lease)> {
        // Explicit forward so the inner backend sees the claimer id
        // (the default falls back to hint-agnostic `receive`).
        if self.partitioned(Duration::ZERO) {
            return None;
        }
        if self.sleep {
            maybe_sleep(self.draws.latency(&self.cfg.recv_lat));
        }
        self.filter(self.inner.receive_for(worker))
    }

    fn receive_timeout(&self, timeout: Duration) -> Option<(String, Lease)> {
        if self.partitioned(timeout) {
            return None;
        }
        if self.sleep {
            maybe_sleep(self.draws.latency(&self.cfg.recv_lat));
        }
        self.filter(self.inner.receive_timeout(timeout))
    }

    fn receive_timeout_for(&self, worker: u64, timeout: Duration) -> Option<(String, Lease)> {
        if self.partitioned(timeout) {
            return None;
        }
        if self.sleep {
            maybe_sleep(self.draws.latency(&self.cfg.recv_lat));
        }
        self.filter(self.inner.receive_timeout_for(worker, timeout))
    }

    fn renew(&self, lease: &Lease) -> bool {
        self.inner.renew(lease)
    }

    fn delete(&self, lease: &Lease) -> bool {
        self.inner.delete(lease)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn visible_len(&self) -> usize {
        self.inner.visible_len()
    }

    fn delivery_count(&self, body: &str) -> u32 {
        self.inner.delivery_count(body)
    }

    fn purge_prefix(&self, body_prefix: &str) -> usize {
        // Control-plane drain — passes through unshaped, like len().
        self.inner.purge_prefix(body_prefix)
    }

    fn set_claim_weights(&self, weights: Arc<crate::storage::traits::ClaimWeights>) {
        // Explicit forward: the trait default would silently drop the
        // fair-share map before it reached a weight-aware backend.
        self.inner.set_claim_weights(weights);
    }
}

// ------------------------------------------------------------------ kv

/// Latency/retry decorator over any [`KvState`]. The trait's
/// operations are infallible by design (the engine's control plane
/// has no retry story for them), so `kv_err` failures are injected as
/// *internal* attempts and absorbed by a bounded retry loop — the
/// caller only ever sees the extra latency.
pub struct ChaosKvState {
    inner: Arc<dyn KvState>,
    cfg: ChaosConfig,
    draws: Draws,
    sleep: bool,
}

impl ChaosKvState {
    pub fn new(inner: Arc<dyn KvState>, cfg: ChaosConfig, sleep: bool) -> Self {
        ChaosKvState {
            inner,
            cfg,
            draws: Draws::new(cfg.seed ^ 0x6B57),
            sleep,
        }
    }

    /// The single shaping point every KV op passes through: each
    /// internal attempt pays one `kv_lat` draw, and with probability
    /// `kv_err` the attempt fails and is retried. The loop is bounded
    /// (≤ 4 attempts) and the final attempt always succeeds, keeping
    /// the trait surface infallible.
    fn pause(&self) {
        for _ in 0..3 {
            if self.sleep {
                maybe_sleep(self.draws.latency(&self.cfg.kv_lat));
            }
            if !self.draws.chance(self.cfg.kv_err) {
                return;
            }
        }
        if self.sleep {
            maybe_sleep(self.draws.latency(&self.cfg.kv_lat));
        }
    }
}

impl KvState for ChaosKvState {
    fn get(&self, key: &str) -> Option<String> {
        self.pause();
        self.inner.get(key)
    }

    fn set(&self, key: &str, value: &str) {
        self.pause();
        self.inner.set(key, value);
    }

    fn set_nx(&self, key: &str, value: &str) -> bool {
        self.pause();
        self.inner.set_nx(key, value)
    }

    fn cas(&self, key: &str, expect: Option<&str>, value: &str) -> bool {
        self.pause();
        self.inner.cas(key, expect, value)
    }

    fn init_counter(&self, key: &str, value: i64) -> bool {
        self.pause();
        self.inner.init_counter(key, value)
    }

    fn incr(&self, key: &str, delta: i64) -> i64 {
        self.pause();
        self.inner.incr(key, delta)
    }

    fn counter(&self, key: &str) -> i64 {
        self.pause();
        self.inner.counter(key)
    }

    fn counter_exists(&self, key: &str) -> bool {
        self.pause();
        self.inner.counter_exists(key)
    }

    fn delete(&self, key: &str) -> bool {
        self.pause();
        self.inner.delete(key)
    }

    fn scan_prefix(&self, prefix: &str) -> Vec<String> {
        self.pause();
        self.inner.scan_prefix(prefix)
    }

    fn delete_prefix(&self, prefix: &str) -> usize {
        self.pause();
        self.inner.delete_prefix(prefix)
    }

    fn edge_decr(&self, edge_key: &str, counter_key: &str) -> i64 {
        self.pause();
        self.inner.edge_decr(edge_key, counter_key)
    }

    fn op_count(&self) -> u64 {
        self.inner.op_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::clock::TestClock;
    use crate::storage::{StrictBlobStore, StrictQueue};

    #[test]
    fn duration_grammar() {
        assert_eq!(parse_duration("5ms").unwrap(), Duration::from_millis(5));
        assert_eq!(parse_duration("250us").unwrap(), Duration::from_micros(250));
        assert_eq!(parse_duration("1.5s").unwrap(), Duration::from_millis(1500));
        assert_eq!(parse_duration("0.25").unwrap(), Duration::from_millis(250));
        assert!(parse_duration("-1ms").is_err());
        assert!(parse_duration("fast").is_err());
    }

    #[test]
    fn latency_dist_grammar_and_samples() {
        let mut rng = Rng::new(1);
        assert_eq!(LatencyDist::parse("off").unwrap(), LatencyDist::Off);
        let f = LatencyDist::parse("5ms").unwrap();
        assert_eq!(f, LatencyDist::Fixed(Duration::from_millis(5)));
        assert_eq!(f.sample(&mut rng), Duration::from_millis(5));
        assert_eq!(
            LatencyDist::parse("fixed:2ms").unwrap(),
            LatencyDist::Fixed(Duration::from_millis(2))
        );
        let u = LatencyDist::parse("uniform:1ms:10ms").unwrap();
        for _ in 0..100 {
            let d = u.sample(&mut rng);
            assert!(d >= Duration::from_millis(1) && d < Duration::from_millis(10));
        }
        let l = LatencyDist::parse("lognorm:5ms").unwrap();
        for _ in 0..100 {
            assert!(l.sample(&mut rng) > Duration::ZERO);
        }
        assert!(LatencyDist::parse("lognorm:5ms:0.9").is_ok());
        assert!(LatencyDist::parse("uniform:10ms:1ms").is_err());
        assert!(LatencyDist::parse("weibull:1ms").is_err());
    }

    #[test]
    fn chaos_config_grammar() {
        let c = ChaosConfig::parse(
            "err=0.01, drop=0.05,dup=0.02,lat=lognorm:5ms,send_lat=2ms,recv_lat=1ms,\
             straggle=0.1:16,partition=0.02:50ms,kv_err=0.1,skew=250ms,seed=9",
        )
        .unwrap();
        assert_eq!(c.err, 0.01);
        assert_eq!(c.drop, 0.05);
        assert_eq!(c.dup, 0.02);
        assert_eq!(
            c.read_lat,
            LatencyDist::LogNormal {
                median: Duration::from_millis(5),
                sigma: 0.5
            }
        );
        assert_eq!(c.write_lat, c.read_lat);
        assert_eq!(c.send_lat, LatencyDist::Fixed(Duration::from_millis(2)));
        assert_eq!(c.recv_lat, LatencyDist::Fixed(Duration::from_millis(1)));
        assert_eq!(c.straggler_frac, 0.1);
        assert_eq!(c.straggler_mult, 16.0);
        assert_eq!(c.partition_frac, 0.02);
        assert_eq!(c.partition_dur, Duration::from_millis(50));
        assert_eq!(c.kv_err, 0.1);
        assert_eq!(c.skew_ns, 250_000_000);
        assert_eq!(c.seed, 9);
        // Skew is signed: negative puts the substrate behind the fleet.
        assert_eq!(ChaosConfig::parse("skew=-50ms").unwrap().skew_ns, -50_000_000);
        assert_eq!(ChaosConfig::parse("skew=2s").unwrap().skew_ns, 2_000_000_000);
        assert_eq!(ChaosConfig::parse("skew=0ms").unwrap().skew_ns, 0);
        assert!(ChaosConfig::parse("skew=soon").is_err());
        // Empty body → all defaults (a no-op layer).
        assert_eq!(ChaosConfig::parse("").unwrap(), ChaosConfig::default());
        assert!(ChaosConfig::parse("err=2").is_err());
        assert!(ChaosConfig::parse("nope=1").is_err());
        assert!(ChaosConfig::parse("straggle=0.5:0.5,lat=1ms").is_err());
        assert!(
            ChaosConfig::parse("straggle=0.5:8").is_err(),
            "straggle without a latency clause is a silent no-op — reject"
        );
        assert!(ChaosConfig::parse("err").is_err());
        assert!(ChaosConfig::parse("partition=0.5").is_err(), "FRAC:DUR required");
        assert!(ChaosConfig::parse("partition=1.5:10ms").is_err());
        assert!(ChaosConfig::parse("partition=0.5:nope").is_err());
        assert!(ChaosConfig::parse("kv_err=2").is_err());
    }

    #[test]
    fn blob_faults_are_transient_marked_and_deterministic() {
        let cfg = ChaosConfig {
            err: 0.4,
            ..ChaosConfig::default()
        };
        let run = || -> Vec<bool> {
            let blob = ChaosBlobStore::new(Arc::new(StrictBlobStore::new()), cfg, true);
            (0..64)
                .map(|i| blob.put(0, &format!("K[{i}]"), Matrix::zeros(1, 1)).is_err())
                .collect()
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b, "same seed, same op order => same fault sequence");
        assert!(a.iter().any(|&x| x), "some ops must fail at err=0.4");
        assert!(a.iter().any(|&x| !x), "some ops must succeed at err=0.4");

        let blob = ChaosBlobStore::new(Arc::new(StrictBlobStore::new()), cfg, true);
        let err = loop {
            match blob.get(0, "missing-and-maybe-faulted") {
                Err(e) if is_transient(&e) => break e,
                Err(_) => continue, // the genuine not-found error
                Ok(_) => unreachable!(),
            }
        };
        // Context wrapping must not hide the marker.
        let wrapped = anyhow::Error::msg(format!("{err:#}")).context("reading tile");
        assert!(is_transient(&wrapped));
    }

    #[test]
    fn blob_delete_faults_are_transient_and_retryable() {
        let cfg = ChaosConfig {
            err: 0.5,
            ..ChaosConfig::default()
        };
        let blob = ChaosBlobStore::new(Arc::new(StrictBlobStore::new()), cfg, true);
        for i in 0..32 {
            // Seed through the retry helper (puts fault too at err=0.5).
            blob_put_with_retry(&blob, 16, 0, &format!("K[{i}]"), Matrix::zeros(1, 1)).unwrap();
        }
        let mut failures = 0;
        for i in 0..32 {
            match blob.delete(&format!("K[{i}]")) {
                Ok(existed) => assert!(existed, "seeded key must exist"),
                Err(e) => {
                    assert!(is_transient(&e), "injected delete fault is transient");
                    failures += 1;
                    // The GC path: retry like a worker would.
                    let existed =
                        with_blob_retry(16, || blob.delete(&format!("K[{i}]"))).unwrap();
                    assert!(existed);
                }
            }
        }
        assert!(failures > 0, "err=0.5 must fault some deletes");
        assert!(blob.is_empty());
        // Prefix ops are infallible even under err.
        blob_put_with_retry(&blob, 16, 0, "j1/A", Matrix::zeros(1, 1)).unwrap();
        assert_eq!(blob.scan_prefix("j1/"), vec!["j1/A".to_string()]);
        assert_eq!(blob.delete_prefix("j1/"), 1);
    }

    #[test]
    fn kv_lifecycle_ops_pass_through_chaos() {
        let cfg = ChaosConfig {
            kv_lat: LatencyDist::Fixed(Duration::from_micros(10)),
            ..ChaosConfig::default()
        };
        let kv = ChaosKvState::new(Arc::new(crate::storage::StrictKvState::new()), cfg, true);
        kv.set("j1/status:a", "completed");
        kv.init_counter("j1/deps:b", 1);
        assert_eq!(kv.scan_prefix("j1/").len(), 2);
        assert!(kv.delete("j1/status:a"));
        assert_eq!(kv.delete_prefix("j1/"), 1);
        assert_eq!(kv.scan_prefix("j1/").len(), 0);
    }

    #[test]
    fn queue_purge_passes_through_chaos() {
        let cfg = ChaosConfig {
            dup: 1.0,
            ..ChaosConfig::default()
        };
        let q = ChaosQueue::new(
            Arc::new(StrictQueue::new(Duration::from_secs(10))),
            cfg,
            true,
        );
        q.send("1|t", 0); // dup=1 → two copies
        q.send("2|t", 0);
        assert_eq!(q.len(), 4);
        assert_eq!(q.purge_prefix("1|"), 2, "both duplicated copies purged");
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn partition_blocks_blob_ops_before_the_backend() {
        let cfg = ChaosConfig {
            partition_frac: 1.0,
            partition_dur: Duration::from_millis(5),
            ..ChaosConfig::default()
        };
        let blob = ChaosBlobStore::new(Arc::new(StrictBlobStore::new()), cfg, true);
        let err = blob.put(0, "K", Matrix::zeros(1, 1)).unwrap_err();
        assert!(is_transient(&err), "partition faults are retryable");
        assert_eq!(blob.len(), 0, "a partitioned put never reaches the backend");
        assert!(blob.delete("K").is_err());
        // Windows heal: at frac<1 a worker-style retry loop gets
        // through once the window lapses.
        let cfg = ChaosConfig {
            partition_frac: 0.5,
            partition_dur: Duration::from_micros(200),
            seed: 7,
            ..ChaosConfig::default()
        };
        let blob = ChaosBlobStore::new(Arc::new(StrictBlobStore::new()), cfg, true);
        blob_put_with_retry(&blob, 64, 0, "K", Matrix::zeros(1, 1)).unwrap();
        assert_eq!(with_blob_retry(64, || blob.get(0, "K")).unwrap().rows(), 1);
    }

    #[test]
    fn partition_starves_receives_without_taking_a_lease() {
        let cfg = ChaosConfig {
            partition_frac: 1.0,
            partition_dur: Duration::from_millis(1),
            ..ChaosConfig::default()
        };
        let q = ChaosQueue::new(
            Arc::new(StrictQueue::new(Duration::from_secs(10))),
            cfg,
            true,
        );
        q.send("t", 0); // sends are unaffected — only receives starve
        assert_eq!(q.len(), 1);
        assert!(q.receive().is_none());
        assert!(q.receive_timeout(Duration::from_millis(5)).is_none());
        // The decisive contrast with drop=: nothing was leased, so the
        // message is still visible and was never counted as delivered.
        assert_eq!(q.visible_len(), 1, "no lease taken while partitioned");
        assert_eq!(q.delivery_count("t"), 0);
    }

    #[test]
    fn kv_err_is_absorbed_by_bounded_internal_retries() {
        let cfg = ChaosConfig {
            kv_err: 1.0,
            kv_lat: LatencyDist::Fixed(Duration::from_millis(2)),
            ..ChaosConfig::default()
        };
        let kv = ChaosKvState::new(Arc::new(crate::storage::StrictKvState::new()), cfg, true);
        let sw = std::time::Instant::now();
        assert_eq!(kv.incr("c", 1), 1);
        assert!(
            sw.elapsed() >= Duration::from_millis(8),
            "kv_err=1 must pay all 4 internal attempts (4 × kv_lat)"
        );
        // Even at kv_err=1 the surface stays infallible and exact.
        for _ in 0..9 {
            kv.incr("c", 1);
        }
        assert_eq!(kv.counter("c"), 10);
        assert!(kv.cas("k", None, "v"));
        assert_eq!(kv.get("k").as_deref(), Some("v"));
    }

    #[test]
    fn real_missing_key_is_not_transient() {
        let cfg = ChaosConfig::default();
        let blob = ChaosBlobStore::new(Arc::new(StrictBlobStore::new()), cfg, true);
        let err = blob.get(0, "nope").unwrap_err();
        assert!(!is_transient(&err));
    }

    #[test]
    fn queue_dup_duplicates_enqueue() {
        let cfg = ChaosConfig {
            dup: 1.0,
            ..ChaosConfig::default()
        };
        let q = ChaosQueue::new(
            Arc::new(StrictQueue::new(Duration::from_secs(10))),
            cfg,
            true,
        );
        q.send("t", 0);
        assert_eq!(q.len(), 2, "dup=1 => every send enqueues twice");
        let (b1, l1) = q.receive().unwrap();
        let (b2, l2) = q.receive().unwrap();
        assert_eq!((b1.as_str(), b2.as_str()), ("t", "t"));
        assert!(q.delete(&l1) && q.delete(&l2));
        assert!(q.is_empty());
    }

    #[test]
    fn queue_forwards_locality_hints_and_claimer_ids() {
        // Frozen clock keeps the hint fresh; hint-aware inner backend.
        let clock = Arc::new(TestClock::default());
        let inner = crate::storage::ShardedQueue::with_clock(1, Duration::from_secs(10), clock);
        let q = ChaosQueue::new(Arc::new(inner), ChaosConfig::default(), true);
        q.send_hinted("for-7", 0, Some(7));
        q.send("anyone", 0);
        // Both the send-side hint and the receive-side claimer id must
        // survive the decorator: worker 9 is steered off the hinted
        // task, worker 7 claims it (also via the blocking variant).
        assert_eq!(q.receive_for(9).unwrap().0, "anyone");
        let (body, _) = q
            .receive_timeout_for(7, Duration::from_millis(50))
            .unwrap();
        assert_eq!(body, "for-7");
    }

    #[test]
    fn queue_drop_loses_delivery_but_lease_expiry_recovers() {
        let clock = Arc::new(TestClock::default());
        let lease = Duration::from_secs(10);
        let inner = StrictQueue::with_clock(lease, clock.clone());
        let cfg = ChaosConfig {
            drop: 1.0,
            ..ChaosConfig::default()
        };
        let q = ChaosQueue::new(Arc::new(inner), cfg, true);
        q.send("t", 0);
        // Delivery swallowed: lease taken, caller sees nothing.
        assert!(q.receive().is_none());
        assert_eq!(q.delivery_count("t"), 1);
        assert_eq!(q.len(), 1, "the message is not lost");
        assert_eq!(q.visible_len(), 0, "…but it is leased");
        // Visibility timeout expires → redeliverable (at-least-once).
        clock.advance(lease + Duration::from_secs(1));
        assert_eq!(q.visible_len(), 1);
        assert!(q.receive().is_none(), "drop=1 swallows again");
        assert_eq!(q.delivery_count("t"), 2);
    }

    #[test]
    fn queue_send_latency_shapes_the_sender() {
        let cfg = ChaosConfig {
            send_lat: LatencyDist::Fixed(Duration::from_millis(5)),
            ..ChaosConfig::default()
        };
        let q = ChaosQueue::new(
            Arc::new(StrictQueue::new(Duration::from_secs(10))),
            cfg,
            true,
        );
        let sw = std::time::Instant::now();
        q.send("t", 0);
        assert!(
            sw.elapsed() >= Duration::from_millis(5),
            "send must pay the shaped enqueue latency"
        );
        // Delivery itself is unshaped and intact.
        let (body, lease) = q.receive().unwrap();
        assert_eq!(body, "t");
        assert!(q.delete(&lease));
        // Virtual-time callers (sleep=false) skip the shaping entirely.
        let q = ChaosQueue::new(
            Arc::new(StrictQueue::new(Duration::from_secs(10))),
            cfg,
            false,
        );
        let sw = std::time::Instant::now();
        q.send("t", 0);
        assert!(sw.elapsed() < Duration::from_millis(5));
    }

    #[test]
    fn straggler_membership_deterministic_and_proportional() {
        let cfg = ChaosConfig {
            straggler_frac: 0.25,
            straggler_mult: 8.0,
            seed: 42,
            ..ChaosConfig::default()
        };
        let n = 1000;
        let hits = (0..n).filter(|&w| cfg.is_straggler(w)).count();
        assert!((150..=350).contains(&hits), "{hits}/1000 stragglers at frac=0.25");
        for w in 0..64 {
            assert_eq!(cfg.is_straggler(w), cfg.is_straggler(w), "stable membership");
        }
        let none = ChaosConfig::default();
        assert!(!(0..64).any(|w| none.is_straggler(w)));
    }

    #[test]
    fn zero_config_layer_is_transparent() {
        let cfg = ChaosConfig::default();
        let q = ChaosQueue::new(
            Arc::new(StrictQueue::new(Duration::from_secs(10))),
            cfg,
            true,
        );
        q.send("a", 1);
        q.send("b", 2);
        assert_eq!(q.len(), 2);
        let (body, lease) = q.receive().unwrap();
        assert_eq!(body, "b");
        assert!(q.renew(&lease));
        assert!(q.delete(&lease));
        let blob = ChaosBlobStore::new(Arc::new(StrictBlobStore::new()), cfg, true);
        blob.put(3, "X", Matrix::zeros(2, 2)).unwrap();
        assert_eq!(blob.get(3, "X").unwrap().rows(), 2);
        assert_eq!(blob.stats().put_ops, 1);
        assert_eq!(blob.worker_stats(3).get_ops, 1);
        assert_eq!(blob.known_workers(), vec![3]);
    }
}
