//! Injectable time for the queue backends.
//!
//! Visibility-timeout semantics depend on "now"; making the clock a
//! trait lets fault-tolerance tests expire leases deterministically
//! and lets the simulator reuse the same semantics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Injectable time source.
pub trait Clock: Send + Sync + 'static {
    fn now(&self) -> Duration;
}

/// Real wall-clock.
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock {
            epoch: Instant::now(),
        }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> Duration {
        self.epoch.elapsed()
    }
}

/// Manually-advanced clock for tests.
#[derive(Default)]
pub struct TestClock {
    now_ns: AtomicU64,
}

impl TestClock {
    pub fn advance(&self, d: Duration) {
        self.now_ns.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }
}

impl Clock for TestClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.now_ns.load(Ordering::SeqCst))
    }
}
