//! Injectable time for the queue backends.
//!
//! Visibility-timeout semantics depend on "now"; making the clock a
//! trait lets fault-tolerance tests expire leases deterministically
//! and lets the simulator reuse the same semantics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Injectable time source.
pub trait Clock: Send + Sync + 'static {
    fn now(&self) -> Duration;
}

/// A clock offset from another by a constant signed skew — the
/// substrate's view of time when its wall clock disagrees with the
/// workers' (the `chaos(skew=…)` clause; see
/// [`crate::storage::chaos`]). A positive skew puts the substrate
/// *ahead* of the fleet, negative *behind* (clamped at the epoch —
/// `Clock::now` is an unsigned duration).
///
/// Because a queue backend both stamps leases and checks their expiry
/// through the *same* clock handle, a constant offset cancels inside
/// the substrate: lease lifetimes are preserved, only the absolute
/// timeline shifts. That invariance is exactly what makes the §4.1
/// at-least-once recovery protocol deployable across machines whose
/// clocks disagree, and the regression tests pin it down.
pub struct SkewClock {
    inner: Arc<dyn Clock>,
    /// Signed offset in nanoseconds added to the inner clock.
    skew_ns: i64,
}

impl SkewClock {
    pub fn new(inner: Arc<dyn Clock>, skew_ns: i64) -> Self {
        SkewClock { inner, skew_ns }
    }
}

impl Clock for SkewClock {
    fn now(&self) -> Duration {
        let base = self.inner.now();
        if self.skew_ns >= 0 {
            base + Duration::from_nanos(self.skew_ns as u64)
        } else {
            base.saturating_sub(Duration::from_nanos(self.skew_ns.unsigned_abs()))
        }
    }
}

/// Real wall-clock.
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock {
            epoch: Instant::now(),
        }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> Duration {
        self.epoch.elapsed()
    }
}

/// Manually-advanced clock for tests.
#[derive(Default)]
pub struct TestClock {
    now_ns: AtomicU64,
}

impl TestClock {
    pub fn advance(&self, d: Duration) {
        self.now_ns.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }
}

impl Clock for TestClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.now_ns.load(Ordering::SeqCst))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skew_clock_offsets_and_clamps() {
        let base = Arc::new(TestClock::default());
        base.advance(Duration::from_millis(100));
        let ahead = SkewClock::new(base.clone(), 50_000_000);
        assert_eq!(ahead.now(), Duration::from_millis(150));
        let behind = SkewClock::new(base.clone(), -30_000_000);
        assert_eq!(behind.now(), Duration::from_millis(70));
        // A skew larger than the inner elapsed time clamps at the
        // epoch instead of underflowing.
        let way_behind = SkewClock::new(base.clone(), -500_000_000);
        assert_eq!(way_behind.now(), Duration::ZERO);
        // The skewed view tracks the inner clock tick for tick.
        base.advance(Duration::from_millis(25));
        assert_eq!(ahead.now(), Duration::from_millis(175));
        assert_eq!(behind.now(), Duration::from_millis(95));
    }
}
