//! Zero-copy tile codec — the one wire/disk format for tiles.
//!
//! Format: 16-byte header (`rows: u64 LE`, `cols: u64 LE`) followed by
//! the row-major `f64` LE payload. Shared by the file blob store and
//! any future network wire, so a tile written by one transport is
//! readable by every other.
//!
//! Encode and decode are single-pass bulk copies over exact-capacity
//! buffers — no per-element `Vec` growth, no intermediate collect. On
//! little-endian targets the payload loop compiles to a straight
//! memcpy-shaped sweep; the code stays portable (`to_le_bytes` /
//! `from_le_bytes` per lane) so big-endian targets still produce the
//! identical on-disk bytes.

use crate::linalg::matrix::Matrix;
use anyhow::{bail, Result};

/// Header bytes preceding the payload.
pub const HEADER_LEN: usize = 16;

/// Exact encoded size of a `rows×cols` tile.
pub fn encoded_len(rows: usize, cols: usize) -> usize {
    HEADER_LEN + rows * cols * 8
}

/// Encode a tile into a fresh exact-capacity buffer.
pub fn encode(m: &Matrix) -> Vec<u8> {
    let mut out = Vec::new();
    encode_into(m, &mut out);
    out
}

/// Encode a tile into `out` (cleared first; capacity is reserved
/// exactly once, so a reused buffer reaches its high-water mark and
/// stops allocating).
pub fn encode_into(m: &Matrix, out: &mut Vec<u8>) {
    let (rows, cols) = (m.rows(), m.cols());
    out.clear();
    out.reserve(encoded_len(rows, cols));
    out.extend_from_slice(&(rows as u64).to_le_bytes());
    out.extend_from_slice(&(cols as u64).to_le_bytes());
    // Bulk payload copy: resize once, then write each 8-byte lane into
    // its slot (no length/capacity checks per element as with repeated
    // `extend_from_slice`).
    out.resize(encoded_len(rows, cols), 0);
    for (chunk, v) in out[HEADER_LEN..].chunks_exact_mut(8).zip(m.data()) {
        chunk.copy_from_slice(&v.to_le_bytes());
    }
}

/// Decode a tile; `key` labels corruption errors. Exact length is
/// enforced — a truncated or padded buffer fails loudly.
pub fn decode(bytes: &[u8], key: &str) -> Result<Matrix> {
    if bytes.len() < HEADER_LEN {
        bail!("corrupt tile `{key}`: {} bytes, header needs 16", bytes.len());
    }
    let rows = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
    let cols = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let want = HEADER_LEN + rows.saturating_mul(cols).saturating_mul(8);
    if bytes.len() != want {
        bail!(
            "corrupt tile `{key}`: {rows}x{cols} header but {} of {want} bytes",
            bytes.len()
        );
    }
    // Single-pass exact-capacity decode.
    let mut data = Vec::with_capacity(rows * cols);
    data.extend(
        bytes[HEADER_LEN..]
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap())),
    );
    Ok(Matrix::from_vec(rows, cols, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_exact_bits() {
        let mut rng = Rng::new(41);
        for (r, c) in [(1, 1), (3, 7), (32, 32), (5, 0), (0, 9)] {
            let m = Matrix::randn(r, c, &mut rng);
            let bytes = encode(&m);
            assert_eq!(bytes.len(), encoded_len(r, c));
            let back = decode(&bytes, "t").unwrap();
            assert_eq!(back, m, "exact f64 bits through the codec");
        }
    }

    #[test]
    fn format_is_pinned() {
        // The on-disk layout is a compatibility contract (durability
        // and recovery tests re-read tiles across processes): header
        // u64 LE dims, then row-major f64 LE.
        let m = Matrix::from_rows(&[&[1.0, -2.5], &[0.25, 3.0]]);
        let bytes = encode(&m);
        assert_eq!(&bytes[0..8], &2u64.to_le_bytes());
        assert_eq!(&bytes[8..16], &2u64.to_le_bytes());
        let lanes: Vec<f64> = bytes[16..]
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        assert_eq!(lanes, vec![1.0, -2.5, 0.25, 3.0]);
    }

    #[test]
    fn encode_into_reuses_capacity() {
        let mut rng = Rng::new(42);
        let big = Matrix::randn(16, 16, &mut rng);
        let small = Matrix::randn(2, 2, &mut rng);
        let mut buf = Vec::new();
        encode_into(&big, &mut buf);
        let cap = buf.capacity();
        encode_into(&small, &mut buf);
        assert_eq!(buf.capacity(), cap, "no shrink/realloc on reuse");
        assert_eq!(decode(&buf, "t").unwrap(), small);
    }

    #[test]
    fn corruption_is_loud() {
        let m = Matrix::zeros(2, 3);
        let mut bytes = encode(&m);
        assert!(decode(&bytes[..10], "k").is_err(), "short header");
        bytes.pop();
        let err = decode(&bytes, "k").unwrap_err().to_string();
        assert!(err.contains("2x3"), "dims in message: {err}");
        let mut fake = Vec::new();
        fake.extend_from_slice(&1000u64.to_le_bytes());
        fake.extend_from_slice(&1000u64.to_le_bytes());
        assert!(decode(&fake, "k").is_err(), "header larger than payload");
    }
}
