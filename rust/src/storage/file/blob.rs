//! Durable tile store: one file per tile, mtimes as `LastModified`.
//!
//! Writes stage in `tmp/` and `rename` into place, so a reader never
//! observes a torn tile and no lock is needed anywhere — last writer
//! wins per key, exactly the S3 model. Ages come from file mtimes
//! (rename preserves the staged file's write time), so
//! `prefix_age`/`prefix_ages` report time-since-newest-put across
//! *processes*, which the in-memory families cannot.
//!
//! Tile format: the shared [`codec`](crate::storage::codec) layout —
//! 16-byte header (`rows: u64 LE`, `cols: u64 LE`) followed by the
//! row-major `f64` LE payload, bulk-copied in one pass. Accounting
//! counts payload bytes (`rows*cols*8`), matching the in-memory
//! families.

use crate::linalg::matrix::Matrix;
use crate::storage::codec;
use crate::storage::file::Layout;
use crate::storage::traits::{BlobStore, StoreStats, TransferAccounting};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

/// The store. Cheap to clone (Arc-shared).
#[derive(Clone)]
pub struct FileBlobStore {
    inner: Arc<Inner>,
}

struct Inner {
    layout: Layout,
    /// In-process transfer accounting (Figure 7's per-worker bytes are
    /// a per-handle metric, not durable state).
    accounting: TransferAccounting,
    /// Injected latency per operation (simulates S3's ~10 ms).
    latency: Duration,
}

impl FileBlobStore {
    pub fn open(dir: &Path, shards: usize) -> Result<FileBlobStore> {
        Self::open_with_latency(dir, shards, Duration::ZERO)
    }

    /// A store that sleeps `latency` on every get/put.
    pub fn open_with_latency(
        dir: &Path,
        shards: usize,
        latency: Duration,
    ) -> Result<FileBlobStore> {
        let layout = Layout::open(dir, shards)
            .with_context(|| format!("file blob store: cannot open `{}`", dir.display()))?;
        Ok(FileBlobStore {
            inner: Arc::new(Inner {
                layout,
                accounting: TransferAccounting::default(),
                latency,
            }),
        })
    }

    fn latency(&self) {
        if !self.inner.latency.is_zero() {
            std::thread::sleep(self.inner.latency);
        }
    }

    fn path(&self, key: &str) -> std::path::PathBuf {
        self.inner.layout.key_path("blob", key)
    }
}

impl BlobStore for FileBlobStore {
    fn put(&self, worker: usize, key: &str, value: Matrix) -> Result<()> {
        self.latency();
        let bytes = (value.rows() * value.cols() * 8) as u64;
        self.inner
            .layout
            .write_atomic(&self.path(key), &codec::encode(&value))
            .with_context(|| format!("file blob store: put `{key}`"))?;
        self.inner.accounting.record_put(worker, bytes);
        Ok(())
    }

    fn get(&self, worker: usize, key: &str) -> Result<Arc<Matrix>> {
        self.latency();
        let raw = std::fs::read(self.path(key))
            .with_context(|| format!("object-store key `{key}` not found"))?;
        let m = codec::decode(&raw, key)?;
        let bytes = (m.rows() * m.cols() * 8) as u64;
        self.inner.accounting.record_get(worker, bytes);
        Ok(Arc::new(m))
    }

    fn contains(&self, key: &str) -> bool {
        self.path(key).exists()
    }

    fn delete(&self, key: &str) -> Result<bool> {
        match std::fs::remove_file(self.path(key)) {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e).with_context(|| format!("file blob store: delete `{key}`")),
        }
    }

    fn scan_prefix(&self, prefix: &str) -> Vec<String> {
        let mut keys: Vec<String> = self
            .inner
            .layout
            .scan_space("blob")
            .into_iter()
            .filter_map(|(k, _)| k.starts_with(prefix).then_some(k))
            .collect();
        keys.sort_unstable();
        keys
    }

    fn delete_prefix(&self, prefix: &str) -> usize {
        let mut removed = 0;
        for (key, path) in self.inner.layout.scan_space("blob") {
            if key.starts_with(prefix) && std::fs::remove_file(path).is_ok() {
                removed += 1;
            }
        }
        removed
    }

    fn prefix_age(&self, prefix: &str) -> Option<Duration> {
        // Min over per-key mtime ages = time since the newest write
        // anywhere under the prefix.
        self.inner
            .layout
            .scan_space("blob")
            .into_iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .filter_map(|(_, p)| super::mtime_age(&p))
            .min()
    }

    fn prefix_ages(&self, delimiter: char) -> Vec<(String, Duration)> {
        // One walk, merging per-namespace minima — the mtime analogue
        // of `traits::PrefixAges` (which is `Instant`-based and so
        // cannot span processes).
        let mut ages: BTreeMap<String, Duration> = BTreeMap::new();
        for (key, path) in self.inner.layout.scan_space("blob") {
            let Some(end) = key.find(delimiter) else {
                continue;
            };
            let Some(age) = super::mtime_age(&path) else {
                continue;
            };
            let ns = key[..end + delimiter.len_utf8()].to_string();
            ages.entry(ns)
                .and_modify(|cur| *cur = (*cur).min(age))
                .or_insert(age);
        }
        ages.into_iter().collect()
    }

    fn len(&self) -> usize {
        self.inner.layout.scan_space("blob").len()
    }

    fn stats(&self) -> StoreStats {
        self.inner.accounting.stats()
    }

    fn worker_stats(&self, worker: usize) -> StoreStats {
        self.inner.accounting.worker_stats(worker)
    }

    fn known_workers(&self) -> Vec<usize> {
        self.inner.accounting.known_workers()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    static SEQ: AtomicU64 = AtomicU64::new(0);

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "npw_fblob_{tag}_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_exact_bits_and_missing_key_errors() {
        let dir = tmpdir("rt");
        let s = FileBlobStore::open(&dir, 4).unwrap();
        let mut rng = Rng::new(7);
        for i in 0..16 {
            let m = Matrix::randn(3, 2, &mut rng);
            let key = format!("j1/T[{i},{}]", i % 5);
            s.put(0, &key, m.clone()).unwrap();
            assert_eq!(*s.get(0, &key).unwrap(), m, "exact f64 bits");
            assert!(s.contains(&key));
        }
        assert_eq!(s.len(), 16);
        assert!(s.get(0, "missing").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn two_handles_share_one_directory() {
        let dir = tmpdir("share");
        let a = FileBlobStore::open(&dir, 4).unwrap();
        let b = FileBlobStore::open(&dir, 4).unwrap();
        a.put(0, "j1/X", Matrix::from_vec(1, 2, vec![1.5, -2.5]))
            .unwrap();
        assert_eq!(b.get(1, "j1/X").unwrap().data(), &[1.5, -2.5]);
        assert!(b.delete("j1/X").unwrap());
        assert!(!a.contains("j1/X"));
        // Accounting is per-handle, not shared state.
        assert_eq!(a.stats().put_ops, 1);
        assert_eq!(b.stats().put_ops, 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lifecycle_ops_sweep_namespaces() {
        let dir = tmpdir("gc");
        let s = FileBlobStore::open(&dir, 4).unwrap();
        for j in 1..=2 {
            for k in 0..8 {
                s.put(0, &format!("j{j}/T[{k}]"), Matrix::zeros(1, 1)).unwrap();
            }
        }
        let j1 = s.scan_prefix("j1/");
        assert_eq!(j1.len(), 8);
        assert!(j1.windows(2).all(|w| w[0] < w[1]), "sorted");
        assert!(s.delete("j1/T[0]").unwrap());
        assert!(!s.delete("j1/T[0]").unwrap());
        assert_eq!(s.delete_prefix("j1/"), 7);
        assert_eq!(s.len(), 8, "j2 untouched");
        assert_eq!(s.delete_prefix(""), 8);
        assert!(s.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prefix_ages_come_from_mtimes() {
        let dir = tmpdir("age");
        let s = FileBlobStore::open(&dir, 4).unwrap();
        assert_eq!(s.prefix_age("j1/"), None);
        for k in 0..4 {
            s.put(0, &format!("j1/T[{k}]"), Matrix::zeros(1, 1)).unwrap();
        }
        std::thread::sleep(Duration::from_millis(12));
        let aged = s.prefix_age("j1/").unwrap();
        assert!(aged >= Duration::from_millis(12));
        // A read must not refresh the age; a write must.
        s.get(0, "j1/T[1]").unwrap();
        assert!(s.prefix_age("j1/").unwrap() >= aged);
        s.put(0, "j1/T[3]", Matrix::zeros(1, 1)).unwrap();
        assert!(s.prefix_age("j1/").unwrap() < aged);
        s.put(0, "j2/T[0]", Matrix::zeros(1, 1)).unwrap();
        let ages = s.prefix_ages('/');
        let names: Vec<&str> = ages.iter().map(|(p, _)| p.as_str()).collect();
        assert_eq!(names, vec!["j1/", "j2/"]);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
