//! Durable runtime state: string KV and counter spaces on disk.
//!
//! Two spaces mirror the in-memory `StrictKvState`: `kv/` holds raw
//! string values, `kvc/` holds counters *and* `edge_decr`'s edge
//! guards as decimal text (job namespaces keep the two disjoint, same
//! as the in-memory families). All mutations — including the two-key
//! `edge_decr` — run under one cross-process [`DirLock`], which is
//! what makes RMW linearizable across an external worker fleet. Reads
//! are lock-free: every write is an atomic rename, so a reader sees
//! either the old or the new value, never a torn one. (A lock-free
//! read can interleave with a concurrent RMW — per-key linearizable
//! reads, exactly the Redis contract, not a snapshot.)
//!
//! This is the store the daemon's crash-restart recovery scans: job
//! manifests, `status:*`, `deps:*`, and `@jN` counters all live here
//! and survive process death.

use crate::storage::file::lock::DirLock;
use crate::storage::file::Layout;
use crate::storage::traits::KvState;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// The store. Cheap to clone (Arc-shared).
#[derive(Clone)]
pub struct FileKvState {
    inner: Arc<Inner>,
}

struct Inner {
    layout: Layout,
    lock: DirLock,
    /// In-process op counter (control-plane load metric, per handle).
    ops: AtomicU64,
}

impl FileKvState {
    pub fn open(dir: &Path, shards: usize) -> anyhow::Result<FileKvState> {
        let layout = Layout::open(dir, shards).map_err(|e| {
            anyhow::anyhow!("file kv state: cannot open `{}`: {e}", dir.display())
        })?;
        let lock = DirLock::new(layout.lock_path("kv.lock"));
        Ok(FileKvState {
            inner: Arc::new(Inner {
                layout,
                lock,
                ops: AtomicU64::new(0),
            }),
        })
    }

    fn bump(&self) {
        self.inner.ops.fetch_add(1, Ordering::Relaxed);
    }

    fn kv_path(&self, key: &str) -> PathBuf {
        self.inner.layout.key_path("kv", key)
    }

    fn ctr_path(&self, key: &str) -> PathBuf {
        self.inner.layout.key_path("kvc", key)
    }

    fn read_counter(&self, key: &str) -> Option<i64> {
        std::fs::read_to_string(self.ctr_path(key))
            .ok()
            .and_then(|s| s.trim().parse().ok())
    }

    fn write_counter(&self, key: &str, value: i64) {
        self.inner
            .layout
            .write_atomic(&self.ctr_path(key), value.to_string().as_bytes())
            .expect("file kv state: counter write failed");
    }
}

impl KvState for FileKvState {
    fn get(&self, key: &str) -> Option<String> {
        self.bump();
        std::fs::read_to_string(self.kv_path(key)).ok()
    }

    fn set(&self, key: &str, value: &str) {
        self.bump();
        let path = self.kv_path(key);
        self.inner.lock.with(|| {
            self.inner
                .layout
                .write_atomic(&path, value.as_bytes())
                .expect("file kv state: set failed");
        });
    }

    fn set_nx(&self, key: &str, value: &str) -> bool {
        self.bump();
        let path = self.kv_path(key);
        self.inner.lock.with(|| {
            if path.exists() {
                return false;
            }
            self.inner
                .layout
                .write_atomic(&path, value.as_bytes())
                .expect("file kv state: set_nx failed");
            true
        })
    }

    fn cas(&self, key: &str, expect: Option<&str>, value: &str) -> bool {
        self.bump();
        let path = self.kv_path(key);
        self.inner.lock.with(|| {
            let current = std::fs::read_to_string(&path).ok();
            if current.as_deref() != expect {
                return false;
            }
            self.inner
                .layout
                .write_atomic(&path, value.as_bytes())
                .expect("file kv state: cas failed");
            true
        })
    }

    fn init_counter(&self, key: &str, value: i64) -> bool {
        self.bump();
        self.inner.lock.with(|| {
            if self.ctr_path(key).exists() {
                return false;
            }
            self.write_counter(key, value);
            true
        })
    }

    fn incr(&self, key: &str, delta: i64) -> i64 {
        self.bump();
        self.inner.lock.with(|| {
            let v = self.read_counter(key).unwrap_or(0) + delta;
            self.write_counter(key, v);
            v
        })
    }

    fn counter(&self, key: &str) -> i64 {
        self.bump();
        self.read_counter(key).unwrap_or(0)
    }

    fn counter_exists(&self, key: &str) -> bool {
        self.ctr_path(key).exists()
    }

    fn delete(&self, key: &str) -> bool {
        self.bump();
        let (kv, ctr) = (self.kv_path(key), self.ctr_path(key));
        self.inner.lock.with(|| {
            let a = std::fs::remove_file(kv).is_ok();
            let b = std::fs::remove_file(ctr).is_ok();
            a || b
        })
    }

    fn scan_prefix(&self, prefix: &str) -> Vec<String> {
        let mut keys: Vec<String> = self
            .inner
            .layout
            .scan_space("kv")
            .into_iter()
            .chain(self.inner.layout.scan_space("kvc"))
            .filter_map(|(k, _)| k.starts_with(prefix).then_some(k))
            .collect();
        keys.sort_unstable();
        keys.dedup();
        keys
    }

    fn delete_prefix(&self, prefix: &str) -> usize {
        self.bump();
        self.inner.lock.with(|| {
            let mut removed = 0;
            for (key, path) in self
                .inner
                .layout
                .scan_space("kv")
                .into_iter()
                .chain(self.inner.layout.scan_space("kvc"))
            {
                if key.starts_with(prefix) && std::fs::remove_file(path).is_ok() {
                    removed += 1;
                }
            }
            removed
        })
    }

    fn edge_decr(&self, edge_key: &str, counter_key: &str) -> i64 {
        self.bump();
        self.inner.lock.with(|| {
            if self.ctr_path(edge_key).exists() {
                // Edge already marked (a re-executed parent): observe
                // the counter without double-decrementing.
                return self.read_counter(counter_key).unwrap_or(0);
            }
            self.write_counter(edge_key, 1);
            let v = self.read_counter(counter_key).unwrap_or(0) - 1;
            self.write_counter(counter_key, v);
            v
        })
    }

    fn op_count(&self) -> u64 {
        self.inner.ops.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    static SEQ: AtomicU64 = AtomicU64::new(0);

    fn open(tag: &str) -> (PathBuf, FileKvState) {
        let d = std::env::temp_dir().join(format!(
            "npw_fkv_{tag}_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        let s = FileKvState::open(&d, 4).unwrap();
        (d, s)
    }

    #[test]
    fn rmw_primitives_match_strict_semantics() {
        let (dir, s) = open("rmw");
        assert!(s.set_nx("k", "a"));
        assert!(!s.set_nx("k", "b"));
        assert_eq!(s.get("k").as_deref(), Some("a"));
        assert!(!s.cas("k", Some("b"), "c"));
        assert!(s.cas("k", Some("a"), "c"));
        assert!(s.cas("new", None, "v"));
        assert!(s.init_counter("n", 5));
        assert!(!s.init_counter("n", 9));
        assert_eq!(s.incr("n", 2), 7);
        assert_eq!(s.decr("fresh"), -1, "incr creates at 0");
        assert_eq!(s.counter("absent"), 0);
        assert!(!s.counter_exists("absent"));
        assert!(s.counter_exists("n"));
        assert!(s.delete("k"));
        assert!(!s.delete("k"));
        assert!(s.op_count() > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn edge_decr_is_idempotent_per_edge_and_durable() {
        let (dir, s) = open("edge");
        s.init_counter("deps:5", 2);
        assert_eq!(s.edge_decr("edge:a->5", "deps:5"), 1);
        assert_eq!(s.edge_decr("edge:a->5", "deps:5"), 1, "re-observed");
        // A second handle on the same dir (≈ another process) sees the
        // mark and the counter.
        let t = FileKvState::open(dir.as_path(), 4).unwrap();
        assert_eq!(t.edge_decr("edge:a->5", "deps:5"), 1);
        assert_eq!(t.edge_decr("edge:b->5", "deps:5"), 0);
        assert_eq!(s.counter("deps:5"), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn scan_and_delete_span_both_spaces() {
        let (dir, s) = open("scan");
        s.set("j1/status:0", "done");
        s.set("j2/status:0", "done");
        s.init_counter("j1/deps:1", 3);
        assert_eq!(s.scan_prefix("j1/"), vec!["j1/deps:1", "j1/status:0"]);
        assert_eq!(s.delete_prefix("j1/"), 2);
        assert_eq!(s.delete_prefix("j1/"), 0, "idempotent");
        assert_eq!(s.scan_prefix(""), vec!["j2/status:0"]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn concurrent_incrs_do_not_lose_updates() {
        let (dir, s) = open("conc");
        let mut handles = Vec::new();
        for _ in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..25 {
                    s.incr("hot", 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.counter("hot"), 200);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
