//! A cross-process directory lock built on `O_EXCL` lock files.
//!
//! Two layers: an in-process mutex (threads of one process never race
//! each other on the disk file) and an on-disk lock file created with
//! `create_new` — the portable atomic-acquire primitive (no `flock`
//! dependency, works on any filesystem that has atomic `open(O_EXCL)`
//! and `rename`). The file holds the owner's pid for debuggability.
//!
//! Liveness: a process that dies while holding the lock leaves the
//! file behind. Waiters steal it once its mtime age exceeds
//! [`STALE_AFTER`] — far longer than any critical section here (all
//! are a handful of small-file IOs) — by renaming it to a unique
//! tombstone first, so exactly one stealer wins even when several
//! notice the stale lock at once.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Age after which a lock file is presumed orphaned by a dead process.
const STALE_AFTER: Duration = Duration::from_secs(10);

/// Retry backoff bounds while the lock is contended.
const BACKOFF_MIN: Duration = Duration::from_micros(100);
const BACKOFF_MAX: Duration = Duration::from_millis(5);

static STEAL_SEQ: AtomicU64 = AtomicU64::new(0);

pub(crate) struct DirLock {
    path: PathBuf,
    local: Mutex<()>,
}

impl DirLock {
    pub(crate) fn new(path: PathBuf) -> DirLock {
        DirLock {
            path,
            local: Mutex::new(()),
        }
    }

    /// Run `f` under both the in-process and the on-disk lock. The
    /// disk lock is released even if `f` panics (guard drop).
    pub(crate) fn with<R>(&self, f: impl FnOnce() -> R) -> R {
        let _local = self.local.lock().unwrap();
        self.acquire_disk();
        let _disk = Release { path: &self.path };
        f()
    }

    fn acquire_disk(&self) {
        let mut backoff = BACKOFF_MIN;
        loop {
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&self.path)
            {
                Ok(f) => {
                    use std::io::Write as _;
                    let _ = writeln!(&f, "{}", std::process::id());
                    return;
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    self.try_steal_stale();
                }
                // Transient fs hiccup (or the locks/ dir racing into
                // existence) — retry like contention.
                Err(_) => {}
            }
            std::thread::sleep(backoff);
            backoff = (backoff * 2).min(BACKOFF_MAX);
        }
    }

    /// If the current lock file has sat past [`STALE_AFTER`], break it.
    /// Rename-to-tombstone makes the steal atomic: of N waiters that
    /// all see the stale file, exactly one rename succeeds, and it
    /// removes the tombstone; everyone then recontends `create_new`.
    fn try_steal_stale(&self) {
        let Some(age) = super::mtime_age(&self.path) else {
            return; // gone already — owner released it
        };
        if age < STALE_AFTER {
            return;
        }
        let tomb = self.path.with_extension(format!(
            "stale.{}.{}",
            std::process::id(),
            STEAL_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        if std::fs::rename(&self.path, &tomb).is_ok() {
            let _ = std::fs::remove_file(&tomb);
        }
    }
}

struct Release<'a> {
    path: &'a PathBuf,
}

impl Drop for Release<'_> {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn lock_in_tmp(tag: &str) -> (PathBuf, DirLock) {
        let dir = std::env::temp_dir().join(format!(
            "npw_lock_test_{tag}_{}_{}",
            std::process::id(),
            STEAL_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.lock");
        (dir, DirLock::new(path))
    }

    #[test]
    fn mutual_exclusion_across_threads() {
        let (dir, lock) = lock_in_tmp("mutex");
        let lock = Arc::new(lock);
        let counter = Arc::new(Mutex::new(0u64));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let (lock, counter) = (lock.clone(), counter.clone());
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    lock.with(|| {
                        let mut c = counter.lock().unwrap();
                        *c += 1;
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*counter.lock().unwrap(), 8 * 50);
        assert!(!lock.path.exists(), "released after last use");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn orphaned_lock_file_is_stolen_once_stale() {
        let (dir, lock) = lock_in_tmp("stale");
        // Fake a dead owner: lock file exists with an ancient mtime.
        std::fs::write(&lock.path, "0\n").unwrap();
        let old = std::time::SystemTime::now() - (STALE_AFTER + Duration::from_secs(5));
        let f = std::fs::OpenOptions::new()
            .write(true)
            .open(&lock.path)
            .unwrap();
        f.set_modified(old).unwrap();
        drop(f);
        // `with` must not deadlock: the stale file is broken and
        // reacquired.
        let ran = lock.with(|| true);
        assert!(ran);
        assert!(!lock.path.exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
