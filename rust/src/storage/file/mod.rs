//! The durable file-backed substrate family — `file:<dir>[:N]`.
//!
//! The paper's architecture survives worker *and* control-plane death
//! because all state lives in durable services (S3/SQS/Redis, §3).
//! The in-memory families forget everything when the process exits;
//! this family keeps the whole substrate on disk, so:
//!
//! * several **processes** can share one substrate (`numpywren worker
//!   --substrate file:<dir>` joins an external fleet),
//! * the daemon can be **killed mid-chain and restarted** against the
//!   same directory — surviving `jN/` namespaces, leases, and `@jN`
//!   dependency counters are re-attached on boot (see
//!   [`crate::daemon`]),
//! * queue **leases survive process death** and expire by wall-clock,
//!   so a dead worker's task is redelivered to a live one exactly as
//!   SQS would.
//!
//! On-disk layout under `<dir>/`:
//!
//! ```text
//! meta                    shard count, pinned by the first open
//! tmp/                    staging for atomic tmp+rename writes
//! locks/kv.lock           cross-process KV mutation lock
//! locks/queue.lock        cross-process queue lock
//! blob/<shard>/<enc-key>  tiles: 16-byte LE header (rows, cols) + f64 LE payload
//! kv/<shard>/<enc-key>    string KV entries (raw value bytes)
//! kvc/<shard>/<enc-key>   counters and edge guards (decimal text)
//! queue/msgs/m-<id>       priority, hint, hint stamp, body
//! queue/leases/l-<id>     receipt, wall-clock deadline, delivery count
//! queue/ids               monotone message-id allocator
//! ```
//!
//! Invariants:
//!
//! * **Every write is atomic** — staged in `tmp/` then `rename`d, the
//!   same idiom as the daemon spool — so readers never observe a torn
//!   file and blob/KV reads need no lock.
//! * **Namespace ages are mtimes.** `prefix_age`/`prefix_ages` reduce
//!   file mtimes exactly as the in-memory families reduce their
//!   `written` instants (reads never refresh an mtime).
//! * **Shard routing is process-stable.** Keys route by the same
//!   FNV-1a hash as the sharded family ([`crate::storage::sharded`]),
//!   never by `RandomState`, so two processes agree on placement. The
//!   shard count itself is pinned in `meta` by the first open; later
//!   opens adopt it regardless of their spec.
//! * **fsync is opt-in.** `NUMPYWREN_FILE_FSYNC=1` (read at open)
//!   syncs every staged file before its rename — crash-consistent at
//!   a large throughput cost; the default trades power-loss safety
//!   (not process-death safety, which rename alone provides) for
//!   speed. `perf_file` measures both.
//!
//! Trait-level error policy: fallible ops (`put`/`get`/`delete`)
//! surface IO errors to the caller's retry budget; infallible ops
//! (KV mutations, queue sends) panic on IO failure — a full disk is a
//! deployment error, not a recoverable fault. The chaos decorators
//! compose over this family unchanged (`file:…+chaos(…)+cache(…)`).

mod blob;
mod kv;
mod lock;
mod queue;

pub use blob::FileBlobStore;
pub use kv::FileKvState;
pub use queue::FileQueue;

use std::io::{self, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, SystemTime};

// Same FNV-1a routing as the sharded family — deterministic across
// processes, unlike `RandomState`.
pub(crate) use crate::storage::sharded::shard_of;

/// The shared on-disk layout handle: root directory, pinned shard
/// count, and the fsync policy. One per backend handle; all handles on
/// one directory agree via `meta`.
pub(crate) struct Layout {
    root: PathBuf,
    shards: usize,
    fsync: bool,
}

/// Process-unique suffix for staged tmp files.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

impl Layout {
    /// Open (creating if needed) the layout rooted at `dir`. The first
    /// open of a directory pins its shard count into `meta`; later
    /// opens adopt the pinned count so cross-process handles agree on
    /// key placement even when their specs differ.
    pub(crate) fn open(dir: &Path, shards: usize) -> io::Result<Layout> {
        let root = dir.to_path_buf();
        std::fs::create_dir_all(root.join("tmp"))?;
        std::fs::create_dir_all(root.join("locks"))?;
        let fsync = std::env::var("NUMPYWREN_FILE_FSYNC").as_deref() == Ok("1");
        let mut layout = Layout {
            root,
            shards: shards.max(1),
            fsync,
        };
        let meta = layout.root.join("meta");
        match std::fs::read_to_string(&meta)
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
        {
            Some(n) if n > 0 => layout.shards = n,
            _ => layout.write_atomic(&meta, layout.shards.to_string().as_bytes())?,
        }
        for space in ["blob", "kv", "kvc"] {
            for s in 0..layout.shards {
                std::fs::create_dir_all(layout.root.join(space).join(s.to_string()))?;
            }
        }
        std::fs::create_dir_all(layout.root.join("queue").join("msgs"))?;
        std::fs::create_dir_all(layout.root.join("queue").join("leases"))?;
        Ok(layout)
    }

    pub(crate) fn root(&self) -> &Path {
        &self.root
    }

    pub(crate) fn lock_path(&self, name: &str) -> PathBuf {
        self.root.join("locks").join(name)
    }

    /// Path of `key` inside `space` (`blob`/`kv`/`kvc`).
    pub(crate) fn key_path(&self, space: &str, key: &str) -> PathBuf {
        let shard = shard_of(key, self.shards);
        self.root
            .join(space)
            .join(shard.to_string())
            .join(encode_key(key))
    }

    /// Stage-then-rename write; readers never see a torn file. The tmp
    /// name is process- and call-unique so concurrent writers (even
    /// across processes) never collide in `tmp/`.
    pub(crate) fn write_atomic(&self, dest: &Path, bytes: &[u8]) -> io::Result<()> {
        let tmp = self.root.join("tmp").join(format!(
            "{}-{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(bytes)?;
            if self.fsync {
                f.sync_all()?;
            }
        }
        let renamed = std::fs::rename(&tmp, dest);
        if renamed.is_err() {
            let _ = std::fs::remove_file(&tmp);
        }
        renamed
    }

    /// Every `(decoded key, path)` in `space`, unsorted. Walks every
    /// numbered shard directory actually present (robust even if a
    /// foreign handle pinned a different count before `meta` existed);
    /// undecodable or foreign filenames are skipped.
    pub(crate) fn scan_space(&self, space: &str) -> Vec<(String, PathBuf)> {
        let mut out = Vec::new();
        let Ok(shards) = std::fs::read_dir(self.root.join(space)) else {
            return out;
        };
        for shard in shards.flatten() {
            let Ok(files) = std::fs::read_dir(shard.path()) else {
                continue;
            };
            for f in files.flatten() {
                if let Some(key) = f.file_name().to_str().and_then(decode_key) {
                    out.push((key, f.path()));
                }
            }
        }
        out
    }
}

/// Percent-encode a substrate key into a filesystem-safe filename.
/// `[A-Za-z0-9._-]` pass through (except a *leading* `.`, so no key
/// can encode to `.` or `..`); everything else — including `/`, the
/// namespace delimiter — becomes `%XX`.
pub(crate) fn encode_key(key: &str) -> String {
    let mut out = String::with_capacity(key.len());
    for b in key.bytes() {
        let safe = matches!(b, b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'_' | b'-')
            || (b == b'.' && !out.is_empty());
        if safe {
            out.push(b as char);
        } else {
            out.push_str(&format!("%{b:02X}"));
        }
    }
    out
}

/// Inverse of [`encode_key`]; `None` for names this module never
/// produced (stray files are ignored, not misread).
pub(crate) fn decode_key(name: &str) -> Option<String> {
    let bytes = name.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            let hex = name.get(i + 1..i + 3)?;
            out.push(u8::from_str_radix(hex, 16).ok()?);
            i += 3;
        } else {
            out.push(bytes[i]);
            i += 1;
        }
    }
    String::from_utf8(out).ok()
}

/// A file's write-idle age: `now - mtime`, saturating at zero (clock
/// skew must never produce a negative age).
pub(crate) fn mtime_age(path: &Path) -> Option<Duration> {
    let mtime = std::fs::metadata(path).ok()?.modified().ok()?;
    Some(
        SystemTime::now()
            .duration_since(mtime)
            .unwrap_or(Duration::ZERO),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "npw_file_test_{tag}_{}_{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn key_encoding_roundtrips_and_is_fs_safe() {
        for key in [
            "j1/T[0,3]",
            "deps:2@i=0,j=1",
            "S[0,3,1]",
            ".",
            "..",
            "a/b/c%d e\tf",
            "",
            "plain-key_1.0",
        ] {
            let enc = encode_key(key);
            assert!(!enc.contains('/'), "{enc}");
            assert_ne!(enc, ".");
            assert_ne!(enc, "..");
            assert_eq!(decode_key(&enc).as_deref(), Some(key), "{enc}");
        }
        assert_eq!(decode_key("%zz"), None);
        assert_eq!(decode_key("%4"), None);
    }

    #[test]
    fn layout_pins_shard_count_in_meta() {
        let dir = tmpdir("meta");
        let a = Layout::open(&dir, 4).unwrap();
        assert_eq!(a.shards, 4);
        // A second open with a different spec adopts the pinned count,
        // so both handles agree on key→shard placement.
        let b = Layout::open(&dir, 16).unwrap();
        assert_eq!(b.shards, 4);
        assert_eq!(
            a.key_path("blob", "j1/T[0]"),
            b.key_path("blob", "j1/T[0]")
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn write_atomic_then_scan_space_decodes_keys() {
        let dir = tmpdir("scan");
        let l = Layout::open(&dir, 3).unwrap();
        for key in ["j1/a", "j1/b", "j2/c"] {
            l.write_atomic(&l.key_path("kv", key), b"v").unwrap();
        }
        let mut keys: Vec<String> = l.scan_space("kv").into_iter().map(|(k, _)| k).collect();
        keys.sort_unstable();
        assert_eq!(keys, ["j1/a", "j1/b", "j2/c"]);
        assert!(l.scan_space("blob").is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
