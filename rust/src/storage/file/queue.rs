//! Durable task queue: message files plus wall-clock lease files.
//!
//! Each message is one file under `queue/msgs/` (priority, locality
//! hint, hint stamp, body); its lease state is a sibling file under
//! `queue/leases/` holding the receipt counter, an **absolute
//! wall-clock deadline**, and the delivery count. Because the deadline
//! is wall-clock (not an in-process `Instant`), a lease taken by a
//! worker that is then `kill -9`ed simply expires on schedule and the
//! message redelivers to any surviving process — the SQS
//! visibility-timeout contract, §4.1's entire fault story, across
//! process boundaries.
//!
//! All queue ops run under one cross-process [`DirLock`]; message ids
//! come from a persistent `queue/ids` allocator, so FIFO-within-
//! priority is global across every process sharing the directory
//! (this family qualifies as an *ordered* backend in the conformance
//! suite's sense, like `strict` and `sharded:1`).
//!
//! Time: deadlines mix an injected [`Clock`] with a wall anchor
//! captured at open — `virtual now = wall-at-open + (clock.now() -
//! clock-at-open)`. Under [`WallClock`](crate::storage::WallClock)
//! that *is* wall time, so independent processes agree on expiry;
//! under a `TestClock` a single process can step lease expiry
//! deterministically, exactly like the in-memory queues.
//!
//! Hint steering mirrors `queue_core::try_receive_for`: within the
//! equal-top-priority group only, a message freshly hinted at another
//! worker is deferred; if the whole group is hinted elsewhere the
//! FIFO-best deferred message is delivered anyway, so steering never
//! starves and never inverts priority.

use crate::storage::clock::Clock;
use crate::storage::file::lock::DirLock;
use crate::storage::file::Layout;
use crate::storage::traits::{ClaimWeights, Lease, Queue};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant, SystemTime};

/// Default hint staleness — matches the sharded family's bound.
const DEFAULT_HINT_STALENESS: Duration = Duration::from_millis(30);

/// The queue. Cheap to clone (Arc-shared).
#[derive(Clone)]
pub struct FileQueue {
    inner: Arc<Inner>,
}

struct Inner {
    layout: Layout,
    lock: DirLock,
    clock: Arc<dyn Clock>,
    default_lease: Duration,
    /// Hint staleness bound, in ms (atomic so the builder can adjust
    /// it on a shared handle).
    hint_staleness_ms: std::sync::atomic::AtomicU64,
    /// `clock.now()` at open — paired with `unix_anchor` to turn the
    /// injected clock into absolute wall milliseconds.
    clock_anchor: Duration,
    /// Wall time (since `UNIX_EPOCH`) at open.
    unix_anchor: Duration,
    /// Per-job fair-share weights ([`Queue::set_claim_weights`]) —
    /// process-local scheduling state, like the in-memory backends;
    /// `None` (and single-job maps) keep the unweighted claim path.
    weights: RwLock<Option<Arc<ClaimWeights>>>,
}

struct Msg {
    id: u64,
    priority: i64,
    hint: Option<u64>,
    hinted_at_ms: u64,
    body: String,
}

struct LeaseFile {
    receipt: u64,
    deadline_ms: u64,
    count: u32,
}

impl FileQueue {
    pub fn open(
        dir: &Path,
        shards: usize,
        default_lease: Duration,
        clock: Arc<dyn Clock>,
    ) -> Result<FileQueue> {
        let layout = Layout::open(dir, shards)
            .with_context(|| format!("file queue: cannot open `{}`", dir.display()))?;
        let lock = DirLock::new(layout.lock_path("queue.lock"));
        let clock_anchor = clock.now();
        let unix_anchor = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .unwrap_or(Duration::ZERO);
        Ok(FileQueue {
            inner: Arc::new(Inner {
                layout,
                lock,
                clock,
                default_lease,
                hint_staleness_ms: std::sync::atomic::AtomicU64::new(
                    DEFAULT_HINT_STALENESS.as_millis() as u64,
                ),
                clock_anchor,
                unix_anchor,
                weights: RwLock::new(None),
            }),
        })
    }

    /// Override the hint staleness bound (tests use a `TestClock`-sized
    /// window; `DEFAULT_HINT_STALENESS` otherwise).
    pub fn with_hint_staleness(self, staleness: Duration) -> FileQueue {
        self.inner.hint_staleness_ms.store(
            staleness.as_millis() as u64,
            std::sync::atomic::Ordering::Relaxed,
        );
        self
    }

    /// Absolute virtual wall time, in ms since the epoch.
    fn now_ms(&self) -> u64 {
        let since_open = self.inner.clock.now().saturating_sub(self.inner.clock_anchor);
        (self.inner.unix_anchor + since_open).as_millis() as u64
    }

    fn msgs_dir(&self) -> PathBuf {
        self.inner.layout.root().join("queue").join("msgs")
    }

    fn msg_path(&self, id: u64) -> PathBuf {
        self.msgs_dir().join(format!("m-{id:020}"))
    }

    fn lease_path(&self, id: u64) -> PathBuf {
        self.inner
            .layout
            .root()
            .join("queue")
            .join("leases")
            .join(format!("l-{id:020}"))
    }

    /// Allocate the next global message id (caller holds the lock).
    fn alloc_id(&self) -> u64 {
        let ids = self.inner.layout.root().join("queue").join("ids");
        let next = std::fs::read_to_string(&ids)
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
            .unwrap_or(1);
        self.inner
            .layout
            .write_atomic(&ids, (next + 1).to_string().as_bytes())
            .expect("file queue: id allocator write failed");
        next
    }

    fn read_lease(&self, id: u64) -> Option<LeaseFile> {
        let raw = std::fs::read_to_string(self.lease_path(id)).ok()?;
        let mut lines = raw.lines();
        Some(LeaseFile {
            receipt: lines.next()?.trim().parse().ok()?,
            deadline_ms: lines.next()?.trim().parse().ok()?,
            count: lines.next()?.trim().parse().ok()?,
        })
    }

    fn write_lease(&self, id: u64, lease: &LeaseFile) {
        let body = format!("{}\n{}\n{}\n", lease.receipt, lease.deadline_ms, lease.count);
        self.inner
            .layout
            .write_atomic(&self.lease_path(id), body.as_bytes())
            .expect("file queue: lease write failed");
    }

    fn read_msg(&self, id: u64, path: &Path) -> Option<Msg> {
        let raw = std::fs::read_to_string(path).ok()?;
        let mut parts = raw.splitn(4, '\n');
        let priority = parts.next()?.trim().parse().ok()?;
        let hint = match parts.next()? {
            "-" => None,
            h => Some(h.trim().parse().ok()?),
        };
        let hinted_at_ms = parts.next()?.trim().parse().ok()?;
        let body = parts.next()?.to_string();
        Some(Msg {
            id,
            priority,
            hint,
            hinted_at_ms,
            body,
        })
    }

    /// Every message, sorted by id (global FIFO order).
    fn list_msgs(&self) -> Vec<Msg> {
        let mut out = Vec::new();
        let Ok(entries) = std::fs::read_dir(self.msgs_dir()) else {
            return out;
        };
        for e in entries.flatten() {
            let name = e.file_name();
            let Some(id) = name
                .to_str()
                .and_then(|n| n.strip_prefix("m-"))
                .and_then(|n| n.parse::<u64>().ok())
            else {
                continue;
            };
            if let Some(m) = self.read_msg(id, &e.path()) {
                out.push(m);
            }
        }
        out.sort_by_key(|m| m.id);
        out
    }

    fn visible(&self, id: u64, now_ms: u64) -> bool {
        match self.read_lease(id) {
            None => true,
            Some(l) => l.deadline_ms <= now_ms,
        }
    }

    /// One receive attempt, mirroring `queue_core::try_receive_for`:
    /// hint steering and fair-share weighting both act within the
    /// equal-top-priority group only, with strict-`>` weight
    /// replacement so equal weights preserve exact FIFO.
    fn try_receive(&self, claimer: Option<u64>) -> Option<(String, Lease)> {
        self.inner.lock.with(|| {
            let now = self.now_ms();
            let mut msgs = self.list_msgs();
            msgs.retain(|m| self.visible(m.id, now));
            // Priority desc, then FIFO (id asc) — the heap order of the
            // in-memory cores.
            msgs.sort_by(|a, b| b.priority.cmp(&a.priority).then(a.id.cmp(&b.id)));
            let staleness_ms = self
                .inner
                .hint_staleness_ms
                .load(std::sync::atomic::Ordering::Relaxed);
            let weights = self.inner.weights.read().unwrap().clone();
            let weights = match (claimer, weights) {
                (Some(_), Some(w)) if w.active() => Some(w),
                _ => None,
            };
            let mut deferred: Option<&Msg> = None;
            let mut chosen: Option<(&Msg, f64)> = None;
            let mut group: Option<i64> = None;
            for m in &msgs {
                if let Some(g) = group {
                    if m.priority < g {
                        // Equal-priority group exhausted; taking this
                        // one would invert priority — fall back to the
                        // best seen so far.
                        break;
                    }
                }
                group = group.or(Some(m.priority));
                let steered_away = match (claimer, m.hint) {
                    (Some(w), Some(h)) => {
                        h != w && now.saturating_sub(m.hinted_at_ms) < staleness_ms
                    }
                    _ => false,
                };
                if steered_away {
                    deferred = deferred.or(Some(m));
                    continue;
                }
                match &weights {
                    None => {
                        chosen = Some((m, 1.0));
                        break;
                    }
                    Some(w) => {
                        let wt = w.weight_of_body(&m.body);
                        match chosen {
                            Some((_, best)) if wt <= best => {}
                            _ => chosen = Some((m, wt)),
                        }
                    }
                }
            }
            let m = chosen.map(|(m, _)| m).or(deferred)?;
            let prev = self.read_lease(m.id);
            let receipt = prev.as_ref().map_or(0, |l| l.receipt) + 1;
            let count = prev.as_ref().map_or(0, |l| l.count) + 1;
            self.write_lease(
                m.id,
                &LeaseFile {
                    receipt,
                    deadline_ms: now + self.inner.default_lease.as_millis() as u64,
                    count,
                },
            );
            Some((
                m.body.clone(),
                Lease {
                    msg_id: m.id,
                    receipt,
                },
            ))
        })
    }

    fn receive_loop(&self, claimer: Option<u64>, timeout: Duration) -> Option<(String, Lease)> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(got) = self.try_receive(claimer) {
                return Some(got);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            std::thread::sleep((deadline - now).min(Duration::from_millis(1)));
        }
    }
}

impl Queue for FileQueue {
    fn send(&self, body: &str, priority: i64) {
        self.send_hinted(body, priority, None);
    }

    fn send_hinted(&self, body: &str, priority: i64, hint: Option<u64>) {
        self.inner.lock.with(|| {
            let id = self.alloc_id();
            let hint_field = match hint {
                Some(h) => h.to_string(),
                None => "-".to_string(),
            };
            let contents = format!("{priority}\n{hint_field}\n{}\n{body}", self.now_ms());
            self.inner
                .layout
                .write_atomic(&self.msg_path(id), contents.as_bytes())
                .expect("file queue: send failed");
        });
    }

    fn receive(&self) -> Option<(String, Lease)> {
        self.try_receive(None)
    }

    fn receive_for(&self, worker: u64) -> Option<(String, Lease)> {
        self.try_receive(Some(worker))
    }

    fn receive_timeout(&self, timeout: Duration) -> Option<(String, Lease)> {
        self.receive_loop(None, timeout)
    }

    fn receive_timeout_for(&self, worker: u64, timeout: Duration) -> Option<(String, Lease)> {
        self.receive_loop(Some(worker), timeout)
    }

    fn renew(&self, lease: &Lease) -> bool {
        self.inner.lock.with(|| {
            if !self.msg_path(lease.msg_id).exists() {
                return false;
            }
            match self.read_lease(lease.msg_id) {
                // Same rule as the in-memory cores: the receipt must be
                // current — an expired-but-not-redelivered lease still
                // renews.
                Some(l) if l.receipt == lease.receipt => {
                    self.write_lease(
                        lease.msg_id,
                        &LeaseFile {
                            receipt: l.receipt,
                            deadline_ms: self.now_ms()
                                + self.inner.default_lease.as_millis() as u64,
                            count: l.count,
                        },
                    );
                    true
                }
                _ => false,
            }
        })
    }

    fn delete(&self, lease: &Lease) -> bool {
        self.inner.lock.with(|| {
            if !self.msg_path(lease.msg_id).exists() {
                return false;
            }
            match self.read_lease(lease.msg_id) {
                Some(l) if l.receipt == lease.receipt => {
                    let _ = std::fs::remove_file(self.msg_path(lease.msg_id));
                    let _ = std::fs::remove_file(self.lease_path(lease.msg_id));
                    true
                }
                _ => false,
            }
        })
    }

    fn len(&self) -> usize {
        self.list_msgs().len()
    }

    fn visible_len(&self) -> usize {
        let now = self.now_ms();
        self.list_msgs()
            .iter()
            .filter(|m| self.visible(m.id, now))
            .count()
    }

    fn delivery_count(&self, body: &str) -> u32 {
        self.list_msgs()
            .iter()
            .find(|m| m.body == body)
            .map(|m| self.read_lease(m.id).map_or(0, |l| l.count))
            .unwrap_or(0)
    }

    fn purge_prefix(&self, body_prefix: &str) -> usize {
        self.inner.lock.with(|| {
            let mut purged = 0;
            for m in self.list_msgs() {
                if m.body.starts_with(body_prefix) {
                    let _ = std::fs::remove_file(self.msg_path(m.id));
                    let _ = std::fs::remove_file(self.lease_path(m.id));
                    purged += 1;
                }
            }
            purged
        })
    }

    fn set_claim_weights(&self, weights: Arc<ClaimWeights>) {
        *self.inner.weights.write().unwrap() = Some(weights);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::clock::{TestClock, WallClock};
    use std::sync::atomic::{AtomicU64, Ordering};

    static SEQ: AtomicU64 = AtomicU64::new(0);

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "npw_fq_{tag}_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn open(dir: &Path, clock: Arc<dyn Clock>) -> FileQueue {
        FileQueue::open(dir, 2, Duration::from_secs(10), clock).unwrap()
    }

    #[test]
    fn fifo_within_priority_and_priority_order() {
        let dir = tmpdir("fifo");
        let q = open(&dir, Arc::new(WallClock::new()));
        q.send("low-1", 0);
        q.send("hi-1", 5);
        q.send("low-2", 0);
        q.send("hi-2", 5);
        let order: Vec<String> = std::iter::from_fn(|| {
            q.receive().map(|(b, l)| {
                assert!(q.delete(&l));
                b
            })
        })
        .collect();
        assert_eq!(order, ["hi-1", "hi-2", "low-1", "low-2"]);
        assert!(q.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lease_expiry_redelivers_with_test_clock() {
        let dir = tmpdir("lease");
        let clock = Arc::new(TestClock::new());
        let q = open(&dir, clock.clone());
        q.send("task", 0);
        let (_, lease) = q.receive().unwrap();
        assert_eq!(q.visible_len(), 0, "leased");
        assert!(q.receive().is_none());
        clock.advance(Duration::from_secs(11));
        let (_, lease2) = q.receive().expect("redelivered after expiry");
        assert_eq!(q.delivery_count("task"), 2);
        // The first lease is stale; renewing it cannot resurrect it.
        assert!(!q.renew(&lease));
        assert!(!q.delete(&lease));
        assert!(q.renew(&lease2));
        assert!(q.delete(&lease2));
        assert!(q.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn expired_but_not_redelivered_lease_still_renews() {
        let dir = tmpdir("exp");
        let clock = Arc::new(TestClock::new());
        let q = open(&dir, clock.clone());
        q.send("t", 0);
        let (_, lease) = q.receive().unwrap();
        clock.advance(Duration::from_secs(11));
        // Nobody re-received it, so the receipt is still current — the
        // in-memory cores accept this renew, and so must we.
        assert!(q.renew(&lease));
        assert_eq!(q.visible_len(), 0, "renewed back to invisible");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn leases_and_messages_survive_handle_drop() {
        let dir = tmpdir("durable");
        {
            let q = open(&dir, Arc::new(WallClock::new()));
            q.send("persisted", 3);
            let _ = q.receive().unwrap();
            // Handle (≈ process) dies holding the lease.
        }
        let q2 = open(&dir, Arc::new(WallClock::new()));
        assert_eq!(q2.len(), 1, "message survived");
        assert_eq!(q2.visible_len(), 0, "still leased by the dead owner");
        assert_eq!(q2.delivery_count("persisted"), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn purge_prefix_stales_held_leases() {
        let dir = tmpdir("purge");
        let q = open(&dir, Arc::new(WallClock::new()));
        q.send("j1|a", 0);
        q.send("j1|b", 0);
        q.send("j2|c", 0);
        let (_, lease) = q.receive().unwrap();
        assert_eq!(q.purge_prefix("j1|"), 2);
        assert!(!q.renew(&lease), "lease on purged message is stale");
        assert!(!q.delete(&lease));
        assert_eq!(q.len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn claim_weights_prefer_the_starved_job_but_never_invert_priority() {
        let dir = tmpdir("weights");
        let q = open(&dir, Arc::new(WallClock::new()));
        let w = Arc::new(ClaimWeights::default());
        w.set(1, 0.5);
        w.set(2, 8.0);
        q.set_claim_weights(w);
        // Equal priority: the starved (heavier) job claims first, then
        // FIFO among the rest.
        q.send("1|a", 0);
        q.send("2|b", 0);
        q.send("1|c", 0);
        let (body, l) = q.receive_for(3).unwrap();
        assert_eq!(body, "2|b");
        assert!(q.delete(&l));
        let (body, l) = q.receive_for(3).unwrap();
        assert_eq!(body, "1|a");
        assert!(q.delete(&l));
        let (body, l) = q.receive_for(3).unwrap();
        assert_eq!(body, "1|c");
        assert!(q.delete(&l));
        // Weight never beats class/line priority.
        q.send("2|low", 1);
        q.send("1|high", 5);
        let (body, l) = q.receive_for(3).unwrap();
        assert_eq!(body, "1|high");
        assert!(q.delete(&l));
        // Plain receive stays weight-agnostic.
        q.send("1|d", 1);
        let (body, l) = q.receive().unwrap();
        assert_eq!(body, "2|low", "FIFO for plain receive");
        assert!(q.delete(&l));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn hints_steer_within_priority_but_never_starve() {
        let dir = tmpdir("hint");
        let q = FileQueue::open(
            &dir,
            2,
            Duration::from_secs(10),
            Arc::new(WallClock::new()),
        )
        .unwrap()
        .with_hint_staleness(Duration::from_secs(5));
        q.send_hinted("for-7", 0, Some(7));
        q.send("unhinted", 0);
        // Worker 9 skips the fresh foreign hint, takes the unhinted one.
        let (body, l) = q.receive_for(9).unwrap();
        assert_eq!(body, "unhinted");
        assert!(q.delete(&l));
        // Whole group hinted elsewhere → FIFO-best delivered anyway.
        let (body, l) = q.receive_for(9).unwrap();
        assert_eq!(body, "for-7");
        assert!(q.delete(&l));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
