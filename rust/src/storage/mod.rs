//! The simulated serverless substrate — now a pluggable layer.
//!
//! numpywren runs on three cloud services (§4, Figure 6). This module
//! abstracts each behind an object-safe trait (see [`traits`]) and
//! ships two interchangeable backend families, selected by
//! [`SubstrateConfig`](crate::config::SubstrateConfig):
//!
//! * **`sharded`** (default) — N-way key-hash sharding with per-shard
//!   locks ([`ShardedBlobStore`], [`ShardedKvState`]) and a sharded
//!   priority queue with work-stealing receive ([`ShardedQueue`]).
//!   This is the high-concurrency family: the real S3/SQS/Redis shard
//!   internally, and a single process mutex must not serialize what
//!   the cloud would not. `sharded:auto` sizes the shard count from
//!   the configured worker pool
//!   ([`shards_for_workers`](crate::config::shards_for_workers)) — the
//!   engine and job manager resolve it from their scaling mode; a
//!   direct [`Substrate::build`] falls back to the machine's
//!   parallelism.
//! * **`strict`** — the original single-lock implementations
//!   ([`StrictBlobStore`], [`StrictQueue`], [`StrictKvState`]):
//!   globally linearizable, exactly-ordered, and able to police SSA
//!   write discipline (`strict_ssa`) — the test and debugging backend.
//! * **`file:<dir>[:N]`** — the durable on-disk family
//!   ([`FileBlobStore`], [`FileQueue`], [`FileKvState`]): every tile,
//!   KV entry, message, and lease is a file under `<dir>`, written
//!   atomically (tmp+rename) and sharded across `N` subdirectories by
//!   the same deterministic hash as the sharded family. State
//!   survives process death: external worker processes
//!   (`numpywren worker --substrate file:<dir>`) share one substrate,
//!   queue leases expire by wall-clock so a killed worker's task
//!   redelivers to a live process, and the daemon recovers in-flight
//!   job chains after a crash-restart (see [`file`] and
//!   [`crate::daemon`]). `file:auto` materializes a fresh temp
//!   directory per build — the CI matrix's per-test isolation.
//!
//! Any family can be wrapped in the **chaos decorator layer**
//! ([`chaos`]) with a `+chaos(…)` suffix on the substrate spec, and/or
//! in the **worker-local tile cache** ([`cache`]) with `+cache(…)`:
//!
//! ```text
//! substrate = sharded:16+chaos(err=0.01,lat=lognorm:5ms)
//! substrate = strict+chaos(drop=0.05,dup=0.05,seed=7)
//! substrate = sharded:8+chaos(lat=uniform:1ms:20ms,straggle=0.1:16)
//! substrate = sharded:auto+cache(bytes=33554432)
//! substrate = sharded:8+cache(bytes=32m)+chaos(err=0.02,seed=7)
//! substrate = file:/var/lib/npw:8+chaos(err=0.02,partition=0.01:50,seed=9)
//! substrate = file:auto+chaos(kv_err=0.05)+cache(bytes=16m)
//! ```
//!
//! The cache always composes **outermost** regardless of its position
//! in the spec: hits are served from worker-local memory (which cannot
//! fault), misses traverse the chaos layer and are retried by the
//! normal worker retry budget. See [`cache`] for the write-through /
//! invalidate-on-lifecycle-op invariants.
//!
//! `err` injects transient blob-op failures (get, put, *and* the
//! lifecycle `delete` — GC callers retry exactly as workers do),
//! `drop`/`dup` make SQS's at-least-once semantics real (lost
//! deliveries recovered by lease expiry, duplicated enqueues absorbed
//! by idempotent execution),
//! `lat`/`read_lat`/`write_lat`/`send_lat`/`recv_lat`/`kv_lat` shape
//! per-op latency (fixed / uniform / lognormal; `send_lat` delays the
//! enqueue itself — the client/worker-side SQS round-trip; `kv_lat`
//! covers the KV lifecycle ops `delete`/`scan_prefix`/`delete_prefix`
//! alongside the RMW primitives; blob `scan_prefix` pays one
//! `read_lat` draw and blob `delete`/`delete_prefix` one `write_lat`
//! draw), `straggle=FRAC:MULT` slows a deterministic fraction of
//! workers for straggler experiments, `partition=FRAC:MS` makes the
//! backend *temporarily unreachable* — with probability FRAC an op
//! opens an MS-millisecond window in which blob get/put/delete fail
//! transiently and queue receives see an empty queue (no lease is
//! taken, so nothing is lost — the S3/SQS brown-out shape), and
//! `kv_err=P` makes each KV RMW internally fail-and-retry with
//! probability P (absorbed by a bounded in-decorator retry loop, so
//! the infallible [`KvState`] contract is preserved while the
//! control plane pays realistic retry latency), and `skew=D`
//! (signed: `skew=-50ms`) offsets the clock the queue backends stamp
//! and expire leases with relative to the fleet's — the cross-machine
//! clock-disagreement scenario; a constant skew must leave lease
//! semantics invariant because take and expiry read the same skewed
//! handle (see [`SkewClock`]). Everything is seeded (`seed=N`) and
//! reproducible. The chaos-wrapped backends pass the
//! same conformance suite — the decorators perturb timing and
//! delivery, never the contracts.
//!
//! **Lifecycle ops** (substrate GC): all three traits expose
//! reclamation — `BlobStore::{delete, scan_prefix, delete_prefix}`,
//! `KvState::{delete, scan_prefix, delete_prefix}`, and
//! [`Queue::purge_prefix`] — so a finished job's `jN/` namespace
//! (tiles, status/deps/edge entries, queue residue) can be swept
//! instead of leaking for the life of the service. See
//! [`crate::jobs`] for the retention policies built on top.
//!
//! Per-service semantics both families guarantee (and the conformance
//! suite in `tests/substrate_conformance.rs` enforces):
//!
//! * [`BlobStore`] — Amazon S3: a keyed tile store with
//!   read-after-write consistency per key, per-operation latency
//!   injection, and byte accounting (Figure 7's network-bytes numbers
//!   come from these counters).
//! * [`Queue`] — Amazon SQS: at-least-once delivery with a visibility
//!   timeout; fetching a task takes a *lease*, renewable by the
//!   worker, and an expired lease makes the task visible again (the
//!   entire §4.1 fault-tolerance protocol rests on this).
//! * [`KvState`] — Redis/ElastiCache: linearizable per-key
//!   compare-and-swap and counters, used for task status and
//!   dependency counting.
//!
//! Time is injectable everywhere a visibility timeout matters — see
//! [`Clock`], [`WallClock`], [`TestClock`].

pub mod cache;
pub mod chaos;
pub mod clock;
pub mod codec;
pub mod file;
pub mod object_store;
pub mod queue;
pub(crate) mod queue_core;
pub mod sharded;
pub mod state_store;
pub mod traits;

pub use cache::{CacheConfig, CacheStats, CachedBlobStore};
pub use chaos::{ChaosBlobStore, ChaosConfig, ChaosKvState, ChaosQueue, LatencyDist};
pub use clock::{Clock, SkewClock, TestClock, WallClock};
pub use file::{FileBlobStore, FileKvState, FileQueue};
pub use object_store::StrictBlobStore;
pub use queue::StrictQueue;
pub use sharded::{ShardedBlobStore, ShardedKvState, ShardedQueue};
pub use state_store::{status, StrictKvState};
pub use traits::{BlobStore, ClaimWeights, KvState, Lease, Queue, StoreStats};

use crate::config::{SubstrateBackend, SubstrateConfig};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// One job's substrate: a blob store, a task queue, and a KV state
/// store, all behind trait handles. Everything above this bundle
/// (engine, executor, provisioner) is backend-agnostic.
#[derive(Clone)]
pub struct Substrate {
    pub blob: Arc<dyn BlobStore>,
    pub queue: Arc<dyn Queue>,
    pub state: Arc<dyn KvState>,
    /// The cache layer's concrete handle when the spec carries a
    /// `+cache(…)` decorator (in that case [`Substrate::blob`] *is*
    /// this store, viewed through the trait). Kept alongside so the
    /// executor can read hit/miss counters and gate the affinity
    /// machinery without downcasting.
    pub cache: Option<Arc<CachedBlobStore>>,
}

impl Substrate {
    /// Build the backend family `cfg` selects, on the wall clock,
    /// wrapped in the chaos and cache layers the config carries.
    pub fn build(cfg: &SubstrateConfig, lease: Duration, store_latency: Duration) -> Substrate {
        Self::build_with_clock(cfg, lease, store_latency, Arc::new(WallClock::new()))
    }

    /// Build with an injected clock (deterministic lease-expiry tests).
    pub fn build_with_clock(
        cfg: &SubstrateConfig,
        lease: Duration,
        store_latency: Duration,
        clock: Arc<dyn Clock>,
    ) -> Substrate {
        let base = Self::build_base(cfg, lease, store_latency, clock);
        let shaped = match cfg.chaos {
            Some(chaos) => base.with_chaos(&chaos, true),
            None => base,
        };
        match cfg.cache {
            // Cache outermost: hits bypass chaos, misses traverse it.
            Some(cache) => shaped.with_cache(&cache),
            None => shaped,
        }
    }

    /// Virtual-time build for the discrete-event simulator: no
    /// injected store latency and chaos latency shaping disabled (the
    /// sim's cost model owns time); fault/drop/dup injection still
    /// applies, so the sim exercises the same at-least-once recovery
    /// machinery as the engine.
    pub fn build_sim(cfg: &SubstrateConfig, lease: Duration, clock: Arc<dyn Clock>) -> Substrate {
        let base = Self::build_base(cfg, lease, Duration::ZERO, clock);
        let shaped = match cfg.chaos {
            Some(chaos) => base.with_chaos(&chaos, false),
            None => base,
        };
        match cfg.cache {
            Some(cache) => shaped.with_cache(&cache),
            None => shaped,
        }
    }

    fn build_base(
        cfg: &SubstrateConfig,
        lease: Duration,
        store_latency: Duration,
        clock: Arc<dyn Clock>,
    ) -> Substrate {
        // `chaos(skew=…)` is a clock perturbation, not an op fault: the
        // queue backends see time through a skewed lens relative to the
        // fleet's clock (workers, monitor, provisioner keep `clock`).
        let clock: Arc<dyn Clock> = match cfg.chaos.map(|c| c.skew_ns).unwrap_or(0) {
            0 => clock,
            ns => Arc::new(clock::SkewClock::new(clock, ns)),
        };
        match &cfg.backend {
            SubstrateBackend::Strict => Substrate {
                blob: Arc::new(StrictBlobStore::with_latency(store_latency)),
                queue: Arc::new(StrictQueue::with_clock(lease, clock)),
                state: Arc::new(StrictKvState::new()),
                cache: None,
            },
            SubstrateBackend::Sharded { shards } => Substrate {
                blob: Arc::new(ShardedBlobStore::with_latency(*shards, store_latency)),
                queue: Arc::new(ShardedQueue::with_clock(*shards, lease, clock)),
                state: Arc::new(ShardedKvState::new(*shards)),
                cache: None,
            },
            // Engine/JobManager resolve `auto` from their configured
            // worker pool before building; reaching here means a direct
            // build (conformance suite, ad-hoc tools) — size from the
            // machine instead.
            SubstrateBackend::ShardedAuto => {
                let workers = std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(crate::config::DEFAULT_SHARDS);
                let resolved = cfg.resolve(workers);
                Self::build_base(&resolved, lease, store_latency, clock)
            }
            // The durable on-disk family. A bad directory is a
            // deployment error, so the infallible builder panics with
            // the path instead of limping on.
            SubstrateBackend::File { dir, shards } => {
                let root = resolve_file_dir(dir);
                let fail = |e: anyhow::Error| -> ! {
                    panic!("file substrate `{}`: {e:#}", root.display())
                };
                Substrate {
                    blob: Arc::new(
                        FileBlobStore::open_with_latency(&root, *shards, store_latency)
                            .unwrap_or_else(|e| fail(e)),
                    ),
                    queue: Arc::new(
                        FileQueue::open(&root, *shards, lease, clock)
                            .unwrap_or_else(|e| fail(e)),
                    ),
                    state: Arc::new(
                        FileKvState::open(&root, *shards).unwrap_or_else(|e| fail(e)),
                    ),
                    cache: None,
                }
            }
        }
    }

    /// Wrap all three handles in the chaos decorators. `sleep` gates
    /// latency shaping (wall-clock callers pass `true`; virtual-time
    /// callers pass `false`) — fault/drop/dup injection always applies.
    pub fn with_chaos(self, cfg: &chaos::ChaosConfig, sleep: bool) -> Substrate {
        Substrate {
            blob: Arc::new(ChaosBlobStore::new(self.blob, *cfg, sleep)),
            queue: Arc::new(ChaosQueue::new(self.queue, *cfg, sleep)),
            state: Arc::new(ChaosKvState::new(self.state, *cfg, sleep)),
            cache: self.cache,
        }
    }

    /// Wrap the blob handle in the worker-local tile cache (see
    /// [`cache`]). Applied outermost by the builders — after any chaos
    /// layer — so cache hits are immune to fault/latency injection.
    pub fn with_cache(self, cfg: &CacheConfig) -> Substrate {
        let Substrate {
            blob,
            queue,
            state,
            cache: _,
        } = self;
        let cached = Arc::new(CachedBlobStore::new(blob, *cfg));
        Substrate {
            blob: cached.clone(),
            queue,
            state,
            cache: Some(cached),
        }
    }
}

/// Turn a `file:` spec directory into a concrete path. The sentinel
/// `auto` materializes a fresh process-unique temp directory per build
/// — per-test isolation for the CI substrate matrix (ephemeral by
/// design; point at a real directory for durability).
fn resolve_file_dir(dir: &str) -> PathBuf {
    static AUTO_SEQ: AtomicU64 = AtomicU64::new(0);
    if dir == "auto" {
        std::env::temp_dir().join(format!(
            "npw_file_auto_{}_{}",
            std::process::id(),
            AUTO_SEQ.fetch_add(1, Ordering::Relaxed)
        ))
    } else {
        PathBuf::from(dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_selects_backend_family() {
        let lease = Duration::from_secs(1);
        for spec in [
            "strict",
            "sharded",
            "sharded:4",
            "sharded:auto",
            "strict+chaos()",
            "sharded:4+chaos(lat=fixed:0us,seed=3)",
            "sharded:4+cache(bytes=1048576)",
            "strict+cache()",
            "sharded:4+cache(bytes=2m)+chaos(lat=fixed:0us,seed=3)",
            "sharded:4+chaos(lat=fixed:0us,seed=3)+cache(bytes=2m)",
            "file:auto",
            "file:auto:4+chaos(lat=fixed:0us,seed=3)",
            "file:auto+cache(bytes=2m)",
            "file:auto:2+chaos(lat=fixed:0us,seed=3)+cache(bytes=2m)",
            "sharded:4+chaos(skew=250ms,seed=3)",
            "file:auto+chaos(skew=-250ms,seed=3)",
        ] {
            let cfg = SubstrateConfig::parse(spec).unwrap();
            let sub = Substrate::build(&cfg, lease, Duration::ZERO);
            // Smoke the three handles through their traits.
            sub.queue.send("t", 0);
            assert_eq!(sub.queue.len(), 1);
            assert!(sub.state.set_nx("k", "v"));
            assert!(!sub.state.set_nx("k", "v"));
            assert!(sub.blob.is_empty());
            assert_eq!(sub.cache.is_some(), spec.contains("+cache"));
        }
    }

    #[test]
    fn cache_layer_composes_outermost_over_chaos() {
        use crate::linalg::matrix::Matrix;
        // Order in the spec must not matter: blob is the cache either way.
        for spec in [
            "strict+cache(bytes=1m)+chaos(lat=fixed:0us,seed=1)",
            "strict+chaos(lat=fixed:0us,seed=1)+cache(bytes=1m)",
            "file:auto+chaos(lat=fixed:0us,seed=1)+cache(bytes=1m)",
        ] {
            let cfg = SubstrateConfig::parse(spec).unwrap();
            let sub = Substrate::build(&cfg, lease_secs(1), Duration::ZERO);
            let cache = sub.cache.as_ref().expect("cache layer present");
            sub.blob.put(0, "k", Matrix::zeros(2, 2)).unwrap();
            sub.blob.get(0, "k").unwrap();
            assert_eq!(cache.cache_stats().hits, 1, "[{spec}]");
        }
    }

    fn lease_secs(s: u64) -> Duration {
        Duration::from_secs(s)
    }
}
