//! The simulated serverless substrate.
//!
//! numpywren runs on three cloud services (§4, Figure 6); this module
//! provides behaviour-preserving local implementations of each (see
//! DESIGN.md §1 for the substitution argument):
//!
//! * [`ObjectStore`] — Amazon S3: a keyed tile store with
//!   read-after-write consistency per key, per-operation latency
//!   injection, and byte accounting (Figure 7's network-bytes numbers
//!   come from these counters).
//! * [`TaskQueue`] — Amazon SQS: at-least-once delivery with a
//!   visibility timeout; fetching a task takes a *lease*, renewable by
//!   the worker, and an expired lease makes the task visible again
//!   (the entire §4.1 fault-tolerance protocol rests on this).
//! * [`StateStore`] — Redis/ElastiCache: linearizable per-key
//!   compare-and-swap and counters, used for task status and
//!   dependency counting.

pub mod object_store;
pub mod queue;
pub mod state_store;

pub use object_store::{ObjectStore, StoreStats};
pub use queue::{Lease, TaskQueue};
pub use state_store::StateStore;
