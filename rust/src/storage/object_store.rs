//! S3-like object store for matrix tiles.
//!
//! Semantics preserved from the real service (the ones the paper's
//! design depends on):
//!
//! * unbounded keyed storage, read-after-write consistency per key;
//! * high throughput but high per-op latency (~10 ms in the paper) —
//!   injectable here so small-scale runs exhibit the same
//!   latency-vs-block-size trade-offs as Figure 10a;
//! * byte/op accounting per logical worker (Figure 7);
//! * single-writer discipline: LAmbdaPACK output locations are SSA, so
//!   a key is only ever written once with one value. Re-writes from
//!   duplicated (straggler / retried) tasks are *idempotent*; the store
//!   tolerates them but can be armed to panic on non-idempotent
//!   rewrites in tests (`strict_ssa`).

use crate::linalg::matrix::Matrix;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

/// Aggregate transfer statistics.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub get_ops: u64,
    pub put_ops: u64,
}

#[derive(Default)]
struct Counters {
    bytes_read: AtomicU64,
    bytes_written: AtomicU64,
    get_ops: AtomicU64,
    put_ops: AtomicU64,
}

/// The store. Cheap to clone (Arc-shared).
#[derive(Clone)]
pub struct ObjectStore {
    inner: Arc<Inner>,
}

struct Inner {
    map: RwLock<HashMap<String, Arc<Matrix>>>,
    totals: Counters,
    /// Per-worker counters (worker id → counters) for Figure 7.
    per_worker: RwLock<HashMap<usize, Arc<Counters>>>,
    /// Injected latency per operation (simulates S3's ~10 ms).
    latency: Duration,
    /// Panic if a key is rewritten with different contents.
    strict_ssa: bool,
}

impl ObjectStore {
    pub fn new() -> Self {
        Self::with_latency(Duration::ZERO)
    }

    /// A store that sleeps `latency` on every get/put.
    pub fn with_latency(latency: Duration) -> Self {
        ObjectStore {
            inner: Arc::new(Inner {
                map: RwLock::new(HashMap::new()),
                totals: Counters::default(),
                per_worker: RwLock::new(HashMap::new()),
                latency,
                strict_ssa: false,
            }),
        }
    }

    /// Test-mode store: any rewrite of a key with *different* bytes
    /// panics (SSA violation); identical rewrites (task re-execution)
    /// are allowed, as the paper's idempotence argument requires.
    pub fn strict_ssa() -> Self {
        ObjectStore {
            inner: Arc::new(Inner {
                map: RwLock::new(HashMap::new()),
                totals: Counters::default(),
                per_worker: RwLock::new(HashMap::new()),
                latency: Duration::ZERO,
                strict_ssa: true,
            }),
        }
    }

    fn worker_counters(&self, worker: usize) -> Arc<Counters> {
        if let Some(c) = self.inner.per_worker.read().unwrap().get(&worker) {
            return c.clone();
        }
        let mut w = self.inner.per_worker.write().unwrap();
        w.entry(worker).or_insert_with(Default::default).clone()
    }

    fn latency(&self) {
        if !self.inner.latency.is_zero() {
            std::thread::sleep(self.inner.latency);
        }
    }

    /// Store a tile under `key`, attributed to `worker`.
    pub fn put(&self, worker: usize, key: &str, value: Matrix) -> Result<()> {
        self.latency();
        let bytes = (value.rows() * value.cols() * 8) as u64;
        {
            let mut map = self.inner.map.write().unwrap();
            if let Some(old) = map.get(key) {
                // SSA: a rewrite must be byte-identical (idempotent
                // re-execution) — enforced in strict mode.
                if self.inner.strict_ssa && old.as_ref() != &value {
                    panic!("SSA violation: key `{key}` rewritten with different contents");
                }
            }
            map.insert(key.to_string(), Arc::new(value));
        }
        self.inner.totals.bytes_written.fetch_add(bytes, Ordering::Relaxed);
        self.inner.totals.put_ops.fetch_add(1, Ordering::Relaxed);
        let wc = self.worker_counters(worker);
        wc.bytes_written.fetch_add(bytes, Ordering::Relaxed);
        wc.put_ops.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Fetch the tile at `key`, attributed to `worker`.
    pub fn get(&self, worker: usize, key: &str) -> Result<Arc<Matrix>> {
        self.latency();
        let v = self
            .inner
            .map
            .read()
            .unwrap()
            .get(key)
            .cloned()
            .with_context(|| format!("object-store key `{key}` not found"))?;
        let bytes = (v.rows() * v.cols() * 8) as u64;
        self.inner.totals.bytes_read.fetch_add(bytes, Ordering::Relaxed);
        self.inner.totals.get_ops.fetch_add(1, Ordering::Relaxed);
        let wc = self.worker_counters(worker);
        wc.bytes_read.fetch_add(bytes, Ordering::Relaxed);
        wc.get_ops.fetch_add(1, Ordering::Relaxed);
        Ok(v)
    }

    /// Does `key` exist? (No latency or accounting — control-plane op.)
    pub fn contains(&self, key: &str) -> bool {
        self.inner.map.read().unwrap().contains_key(key)
    }

    /// Number of stored objects.
    pub fn len(&self) -> usize {
        self.inner.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Aggregate stats.
    pub fn stats(&self) -> StoreStats {
        StoreStats {
            bytes_read: self.inner.totals.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.inner.totals.bytes_written.load(Ordering::Relaxed),
            get_ops: self.inner.totals.get_ops.load(Ordering::Relaxed),
            put_ops: self.inner.totals.put_ops.load(Ordering::Relaxed),
        }
    }

    /// Per-worker stats (Figure 7's per-machine bytes).
    pub fn worker_stats(&self, worker: usize) -> StoreStats {
        let w = self.inner.per_worker.read().unwrap();
        match w.get(&worker) {
            Some(c) => StoreStats {
                bytes_read: c.bytes_read.load(Ordering::Relaxed),
                bytes_written: c.bytes_written.load(Ordering::Relaxed),
                get_ops: c.get_ops.load(Ordering::Relaxed),
                put_ops: c.put_ops.load(Ordering::Relaxed),
            },
            None => StoreStats::default(),
        }
    }

    /// Ids of workers that have touched the store.
    pub fn known_workers(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.inner.per_worker.read().unwrap().keys().copied().collect();
        v.sort_unstable();
        v
    }
}

impl Default for ObjectStore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn put_get_roundtrip() {
        let s = ObjectStore::new();
        let mut rng = Rng::new(1);
        let m = Matrix::randn(4, 4, &mut rng);
        s.put(0, "A[0,0]", m.clone()).unwrap();
        assert_eq!(*s.get(0, "A[0,0]").unwrap(), m);
    }

    #[test]
    fn missing_key_errors() {
        let s = ObjectStore::new();
        assert!(s.get(0, "nope").is_err());
    }

    #[test]
    fn read_after_write_consistency_across_threads() {
        let s = ObjectStore::new();
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let m = Matrix::from_vec(1, 1, vec![t as f64]);
                s.put(t, &format!("K[{t}]"), m).unwrap();
                // Own write immediately visible.
                assert_eq!(s.get(t, &format!("K[{t}]")).unwrap()[(0, 0)], t as f64);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn byte_accounting() {
        let s = ObjectStore::new();
        let m = Matrix::zeros(4, 8); // 256 bytes
        s.put(3, "X[0]", m).unwrap();
        s.get(3, "X[0]").unwrap();
        s.get(4, "X[0]").unwrap();
        let t = s.stats();
        assert_eq!(t.bytes_written, 256);
        assert_eq!(t.bytes_read, 512);
        assert_eq!(t.put_ops, 1);
        assert_eq!(t.get_ops, 2);
        assert_eq!(s.worker_stats(3).bytes_read, 256);
        assert_eq!(s.worker_stats(4).bytes_read, 256);
        assert_eq!(s.worker_stats(4).bytes_written, 0);
    }

    #[test]
    fn idempotent_rewrite_allowed_in_strict_mode() {
        let s = ObjectStore::strict_ssa();
        let m = Matrix::zeros(2, 2);
        s.put(0, "A[0]", m.clone()).unwrap();
        s.put(0, "A[0]", m).unwrap(); // same contents — fine
    }

    #[test]
    #[should_panic(expected = "SSA violation")]
    fn conflicting_rewrite_panics_in_strict_mode() {
        let s = ObjectStore::strict_ssa();
        s.put(0, "A[0]", Matrix::zeros(2, 2)).unwrap();
        s.put(0, "A[0]", Matrix::eye(2)).unwrap();
    }

    #[test]
    fn latency_is_injected() {
        let s = ObjectStore::with_latency(Duration::from_millis(5));
        let sw = crate::util::timer::Stopwatch::start();
        s.put(0, "A[0]", Matrix::zeros(1, 1)).unwrap();
        s.get(0, "A[0]").unwrap();
        assert!(sw.elapsed() >= Duration::from_millis(10));
    }
}
