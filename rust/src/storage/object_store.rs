//! Single-lock S3-like object store — the `strict` blob backend.
//!
//! Semantics preserved from the real service (the ones the paper's
//! design depends on):
//!
//! * unbounded keyed storage, read-after-write consistency per key;
//! * high throughput but high per-op latency (~10 ms in the paper) —
//!   injectable here so small-scale runs exhibit the same
//!   latency-vs-block-size trade-offs as Figure 10a;
//! * byte/op accounting per logical worker (Figure 7);
//! * single-writer discipline: LAmbdaPACK output locations are SSA, so
//!   a key is only ever written once with one value. Re-writes from
//!   duplicated (straggler / retried) tasks are *idempotent*; the store
//!   tolerates them but can be armed to panic on non-idempotent
//!   rewrites in tests (`strict_ssa`) — the reason this single-lock
//!   implementation stays around as the test backend after the sharded
//!   family became the default.

use crate::linalg::matrix::Matrix;
use crate::storage::traits::{BlobStore, PrefixAges, StoreStats, Stored, TransferAccounting};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

/// The store. Cheap to clone (Arc-shared).
#[derive(Clone)]
pub struct StrictBlobStore {
    inner: Arc<Inner>,
}

struct Inner {
    map: RwLock<HashMap<String, Stored>>,
    accounting: TransferAccounting,
    /// Injected latency per operation (simulates S3's ~10 ms).
    latency: Duration,
    /// Panic if a key is rewritten with different contents.
    strict_ssa: bool,
}

impl StrictBlobStore {
    pub fn new() -> Self {
        Self::with_latency(Duration::ZERO)
    }

    /// A store that sleeps `latency` on every get/put.
    pub fn with_latency(latency: Duration) -> Self {
        StrictBlobStore {
            inner: Arc::new(Inner {
                map: RwLock::new(HashMap::new()),
                accounting: TransferAccounting::default(),
                latency,
                strict_ssa: false,
            }),
        }
    }

    /// Test-mode store: any rewrite of a key with *different* bytes
    /// panics (SSA violation); identical rewrites (task re-execution)
    /// are allowed, as the paper's idempotence argument requires.
    pub fn strict_ssa() -> Self {
        StrictBlobStore {
            inner: Arc::new(Inner {
                map: RwLock::new(HashMap::new()),
                accounting: TransferAccounting::default(),
                latency: Duration::ZERO,
                strict_ssa: true,
            }),
        }
    }

    fn latency(&self) {
        if !self.inner.latency.is_zero() {
            std::thread::sleep(self.inner.latency);
        }
    }
}

impl Default for StrictBlobStore {
    fn default() -> Self {
        Self::new()
    }
}

impl BlobStore for StrictBlobStore {
    fn put(&self, worker: usize, key: &str, value: Matrix) -> Result<()> {
        self.latency();
        let bytes = (value.rows() * value.cols() * 8) as u64;
        {
            let mut map = self.inner.map.write().unwrap();
            if let Some(old) = map.get(key) {
                // SSA: a rewrite must be byte-identical (idempotent
                // re-execution) — enforced in strict mode.
                if self.inner.strict_ssa && old.tile.as_ref() != &value {
                    panic!("SSA violation: key `{key}` rewritten with different contents");
                }
            }
            map.insert(key.to_string(), Stored::new(value));
        }
        self.inner.accounting.record_put(worker, bytes);
        Ok(())
    }

    fn get(&self, worker: usize, key: &str) -> Result<Arc<Matrix>> {
        self.latency();
        let v = self
            .inner
            .map
            .read()
            .unwrap()
            .get(key)
            .map(|s| s.tile.clone())
            .with_context(|| format!("object-store key `{key}` not found"))?;
        let bytes = (v.rows() * v.cols() * 8) as u64;
        self.inner.accounting.record_get(worker, bytes);
        Ok(v)
    }

    fn contains(&self, key: &str) -> bool {
        self.inner.map.read().unwrap().contains_key(key)
    }

    fn delete(&self, key: &str) -> Result<bool> {
        Ok(self.inner.map.write().unwrap().remove(key).is_some())
    }

    fn scan_prefix(&self, prefix: &str) -> Vec<String> {
        let map = self.inner.map.read().unwrap();
        let mut keys: Vec<String> = map
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        keys.sort_unstable();
        keys
    }

    fn delete_prefix(&self, prefix: &str) -> usize {
        let mut map = self.inner.map.write().unwrap();
        let before = map.len();
        map.retain(|k, _| !k.starts_with(prefix));
        before - map.len()
    }

    fn prefix_age(&self, prefix: &str) -> Option<Duration> {
        let now = Instant::now();
        self.inner
            .map
            .read()
            .unwrap()
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(_, s)| now.saturating_duration_since(s.written))
            .min()
    }

    fn prefix_ages(&self, delimiter: char) -> Vec<(String, Duration)> {
        let mut acc = PrefixAges::new(delimiter);
        for (k, s) in self.inner.map.read().unwrap().iter() {
            acc.observe(k, s.written);
        }
        acc.finish()
    }

    fn len(&self) -> usize {
        self.inner.map.read().unwrap().len()
    }

    fn stats(&self) -> StoreStats {
        self.inner.accounting.stats()
    }

    fn worker_stats(&self, worker: usize) -> StoreStats {
        self.inner.accounting.worker_stats(worker)
    }

    fn known_workers(&self) -> Vec<usize> {
        self.inner.accounting.known_workers()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn put_get_roundtrip() {
        let s = StrictBlobStore::new();
        let mut rng = Rng::new(1);
        let m = Matrix::randn(4, 4, &mut rng);
        s.put(0, "A[0,0]", m.clone()).unwrap();
        assert_eq!(*s.get(0, "A[0,0]").unwrap(), m);
    }

    #[test]
    fn missing_key_errors() {
        let s = StrictBlobStore::new();
        assert!(s.get(0, "nope").is_err());
    }

    #[test]
    fn read_after_write_consistency_across_threads() {
        let s = StrictBlobStore::new();
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let m = Matrix::from_vec(1, 1, vec![t as f64]);
                s.put(t, &format!("K[{t}]"), m).unwrap();
                // Own write immediately visible.
                assert_eq!(s.get(t, &format!("K[{t}]")).unwrap()[(0, 0)], t as f64);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn byte_accounting() {
        let s = StrictBlobStore::new();
        let m = Matrix::zeros(4, 8); // 256 bytes
        s.put(3, "X[0]", m).unwrap();
        s.get(3, "X[0]").unwrap();
        s.get(4, "X[0]").unwrap();
        let t = s.stats();
        assert_eq!(t.bytes_written, 256);
        assert_eq!(t.bytes_read, 512);
        assert_eq!(t.put_ops, 1);
        assert_eq!(t.get_ops, 2);
        assert_eq!(s.worker_stats(3).bytes_read, 256);
        assert_eq!(s.worker_stats(4).bytes_read, 256);
        assert_eq!(s.worker_stats(4).bytes_written, 0);
    }

    #[test]
    fn idempotent_rewrite_allowed_in_strict_mode() {
        let s = StrictBlobStore::strict_ssa();
        let m = Matrix::zeros(2, 2);
        s.put(0, "A[0]", m.clone()).unwrap();
        s.put(0, "A[0]", m).unwrap(); // same contents — fine
    }

    #[test]
    #[should_panic(expected = "SSA violation")]
    fn conflicting_rewrite_panics_in_strict_mode() {
        let s = StrictBlobStore::strict_ssa();
        s.put(0, "A[0]", Matrix::zeros(2, 2)).unwrap();
        s.put(0, "A[0]", Matrix::eye(2)).unwrap();
    }

    #[test]
    fn delete_and_prefix_sweep() {
        let s = StrictBlobStore::new();
        for (j, k) in [(1, 0), (1, 1), (2, 0)] {
            s.put(0, &format!("j{j}/T[{k}]"), Matrix::zeros(1, 1)).unwrap();
        }
        assert_eq!(
            s.scan_prefix("j1/"),
            vec!["j1/T[0]".to_string(), "j1/T[1]".to_string()]
        );
        assert!(s.delete("j1/T[0]").unwrap());
        assert!(!s.delete("j1/T[0]").unwrap(), "second delete is a no-op");
        assert!(!s.contains("j1/T[0]"));
        assert_eq!(s.delete_prefix("j1/"), 1);
        assert_eq!(s.delete_prefix("j1/"), 0, "idempotent");
        assert_eq!(s.len(), 1, "other namespaces untouched");
        assert!(s.contains("j2/T[0]"));
    }

    #[test]
    fn prefix_age_tracks_newest_write_only() {
        let s = StrictBlobStore::new();
        assert_eq!(s.prefix_age("j1/"), None, "no keys, no age");
        s.put(0, "j1/T[0]", Matrix::zeros(1, 1)).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        let aged = s.prefix_age("j1/").unwrap();
        assert!(aged >= Duration::from_millis(10));
        // A read must not refresh the namespace.
        s.get(0, "j1/T[0]").unwrap();
        assert!(s.prefix_age("j1/").unwrap() >= Duration::from_millis(10));
        // A new write resets the age to the newest object.
        s.put(0, "j1/T[1]", Matrix::zeros(1, 1)).unwrap();
        assert!(s.prefix_age("j1/").unwrap() < aged);
        assert_eq!(s.prefix_age("j2/"), None);
        // Bulk form: one scan, grouped by delimiter, delimiter-less
        // keys skipped.
        s.put(0, "j2/T[0]", Matrix::zeros(1, 1)).unwrap();
        s.put(0, "loose-key", Matrix::zeros(1, 1)).unwrap();
        let ages = s.prefix_ages('/');
        let names: Vec<&str> = ages.iter().map(|(p, _)| p.as_str()).collect();
        assert_eq!(names, vec!["j1/", "j2/"]);
    }

    #[test]
    fn latency_is_injected() {
        let s = StrictBlobStore::with_latency(Duration::from_millis(5));
        let sw = crate::util::timer::Stopwatch::start();
        s.put(0, "A[0]", Matrix::zeros(1, 1)).unwrap();
        s.get(0, "A[0]").unwrap();
        assert!(sw.elapsed() >= Duration::from_millis(10));
    }
}
