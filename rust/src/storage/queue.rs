//! Single-lock SQS-like task queue — the `strict` queue backend.
//!
//! Guarantees modelled after the real service, exactly the ones §4.1
//! relies on:
//!
//! * **at-least-once delivery** — a message can be delivered to more
//!   than one worker (after lease expiry), never zero;
//! * **visibility timeout** — a fetched message becomes invisible for
//!   the lease duration; the holder may renew; expiry re-exposes it;
//! * **delete-after-complete** — the invariant that a task is removed
//!   only once its effects are durable lives in the *executor*, not
//!   here; the queue just provides `delete` keyed by the lease;
//! * no exactly-once (the paper: "numpywren does not require strong
//!   guarantees … at-least-once is enough"), but deterministic order:
//!   highest priority first, FIFO within a priority by the global
//!   message id — the one guarantee the sharded backend relaxes
//!   across shards.
//!
//! Time is injectable (a [`Clock`]) so fault-tolerance tests can expire
//! leases deterministically and the simulator can reuse the semantics.
//! The message/heap mechanics live in `QueueCore`
//! (`storage::queue_core`, crate-private), shared with the sharded
//! backend.

use crate::storage::clock::{Clock, WallClock};
use crate::storage::queue_core::QueueCore;
use crate::storage::traits::{Lease, Queue};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct Inner {
    core: QueueCore,
    next_id: u64,
}

/// The queue. Clone-shared.
#[derive(Clone)]
pub struct StrictQueue {
    inner: Arc<(Mutex<Inner>, Condvar)>,
    clock: Arc<dyn Clock>,
    default_lease: Duration,
}

impl StrictQueue {
    pub fn new(default_lease: Duration) -> Self {
        Self::with_clock(default_lease, Arc::new(WallClock::new()))
    }

    pub fn with_clock(default_lease: Duration, clock: Arc<dyn Clock>) -> Self {
        StrictQueue {
            inner: Arc::new((
                Mutex::new(Inner {
                    core: QueueCore::default(),
                    next_id: 1,
                }),
                Condvar::new(),
            )),
            clock,
            default_lease,
        }
    }
}

impl Queue for StrictQueue {
    /// Enqueue a message (highest `priority` delivered first among
    /// visible messages; FIFO within a priority).
    fn send(&self, body: &str, priority: i64) {
        let (lock, cv) = &*self.inner;
        let mut q = lock.lock().unwrap();
        let id = q.next_id;
        q.next_id += 1;
        q.core.insert(id, body, priority);
        cv.notify_one();
    }

    fn receive(&self) -> Option<(String, Lease)> {
        let now = self.clock.now();
        let (lock, _) = &*self.inner;
        lock.lock()
            .unwrap()
            .core
            .try_receive(now, self.default_lease)
    }

    /// Blocking receive with timeout. Returns `None` on timeout. The
    /// wait and the visibility check share one lock acquisition, so a
    /// concurrent `send`'s notification cannot be lost.
    fn receive_timeout(&self, timeout: Duration) -> Option<(String, Lease)> {
        let deadline = Instant::now() + timeout;
        let (lock, cv) = &*self.inner;
        let mut q = lock.lock().unwrap();
        loop {
            if let Some(x) = q.core.try_receive(self.clock.now(), self.default_lease) {
                return Some(x);
            }
            let remaining = deadline.checked_duration_since(Instant::now())?;
            // Cap the park: lease expiry does not notify, so re-check
            // periodically.
            let (guard, _) = cv
                .wait_timeout(q, remaining.min(Duration::from_millis(10)))
                .unwrap();
            q = guard;
        }
    }

    fn renew(&self, lease: &Lease) -> bool {
        let now = self.clock.now();
        let (lock, _) = &*self.inner;
        lock.lock()
            .unwrap()
            .core
            .renew(lease, now, self.default_lease)
    }

    fn delete(&self, lease: &Lease) -> bool {
        let (lock, _) = &*self.inner;
        lock.lock().unwrap().core.delete(lease)
    }

    fn len(&self) -> usize {
        self.inner.0.lock().unwrap().core.len()
    }

    fn visible_len(&self) -> usize {
        let now = self.clock.now();
        self.inner.0.lock().unwrap().core.visible_len(now)
    }

    fn delivery_count(&self, body: &str) -> u32 {
        self.inner
            .0
            .lock()
            .unwrap()
            .core
            .delivery_count(body)
            .unwrap_or(0)
    }

    fn purge_prefix(&self, body_prefix: &str) -> usize {
        self.inner.0.lock().unwrap().core.purge_prefix(body_prefix)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::clock::TestClock;

    fn queue_with_test_clock(lease: Duration) -> (StrictQueue, Arc<TestClock>) {
        let clock = Arc::new(TestClock::default());
        (
            StrictQueue::with_clock(lease, clock.clone() as Arc<dyn Clock>),
            clock,
        )
    }

    #[test]
    fn send_receive_delete() {
        let q = StrictQueue::new(Duration::from_secs(10));
        q.send("t1", 0);
        let (body, lease) = q.receive().unwrap();
        assert_eq!(body, "t1");
        assert!(q.receive().is_none(), "invisible while leased");
        assert!(q.delete(&lease));
        assert!(q.is_empty());
    }

    #[test]
    fn priority_order() {
        let q = StrictQueue::new(Duration::from_secs(10));
        q.send("low", 1);
        q.send("high", 5);
        q.send("mid", 3);
        assert_eq!(q.receive().unwrap().0, "high");
        assert_eq!(q.receive().unwrap().0, "mid");
        assert_eq!(q.receive().unwrap().0, "low");
    }

    #[test]
    fn fifo_within_priority() {
        let q = StrictQueue::new(Duration::from_secs(10));
        q.send("first", 0);
        q.send("second", 0);
        assert_eq!(q.receive().unwrap().0, "first");
        assert_eq!(q.receive().unwrap().0, "second");
    }

    #[test]
    fn lease_expiry_redelivers() {
        let (q, clock) = queue_with_test_clock(Duration::from_secs(10));
        q.send("t", 0);
        let (_, lease1) = q.receive().unwrap();
        assert!(q.receive().is_none());
        clock.advance(Duration::from_secs(11));
        // Lease expired → visible again (at-least-once).
        let (_, lease2) = q.receive().unwrap();
        assert_eq!(q.delivery_count("t"), 2);
        // Stale lease can neither renew nor delete.
        assert!(!q.renew(&lease1));
        assert!(!q.delete(&lease1));
        // Fresh lease works.
        assert!(q.delete(&lease2));
    }

    #[test]
    fn renewal_keeps_invisible() {
        let (q, clock) = queue_with_test_clock(Duration::from_secs(10));
        q.send("t", 0);
        let (_, lease) = q.receive().unwrap();
        clock.advance(Duration::from_secs(8));
        assert!(q.renew(&lease));
        clock.advance(Duration::from_secs(8));
        // 16s since receive but renewed at 8s → still invisible.
        assert!(q.receive().is_none());
        clock.advance(Duration::from_secs(3));
        assert!(q.receive().is_some());
    }

    #[test]
    fn delete_only_once_effects_durable_invariant() {
        // The queue-side mechanics of §4.1: a worker that dies after
        // completing the work but before delete → message redelivered;
        // second worker's delete succeeds.
        let (q, clock) = queue_with_test_clock(Duration::from_secs(5));
        q.send("task", 0);
        let (_, _dead_lease) = q.receive().unwrap(); // crashed: never deletes
        clock.advance(Duration::from_secs(6));
        let (_, lease) = q.receive().unwrap();
        assert!(q.delete(&lease));
        assert!(q.is_empty());
    }

    #[test]
    fn receive_timeout_blocks_until_send() {
        let q = StrictQueue::new(Duration::from_secs(10));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.receive_timeout(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(30));
        q.send("x", 0);
        let got = h.join().unwrap();
        assert_eq!(got.unwrap().0, "x");
    }

    #[test]
    fn receive_timeout_times_out() {
        let q = StrictQueue::new(Duration::from_secs(10));
        assert!(q.receive_timeout(Duration::from_millis(30)).is_none());
    }

    #[test]
    fn concurrent_receivers_each_get_distinct_messages() {
        let q = StrictQueue::new(Duration::from_secs(30));
        for i in 0..64 {
            q.send(&format!("m{i}"), 0);
        }
        let mut handles = Vec::new();
        for _ in 0..8 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some((body, lease)) = q.receive() {
                    got.push(body);
                    q.delete(&lease);
                }
                got
            }));
        }
        let mut all: Vec<String> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 64, "each message delivered exactly once here");
    }

    #[test]
    fn stale_heap_entries_skipped() {
        // Re-sent priorities + deletes leave stale heap entries; the
        // queue must never deliver a deleted message.
        let q = StrictQueue::new(Duration::from_secs(10));
        q.send("a", 1);
        q.send("b", 2);
        let (b, lease_b) = q.receive().unwrap();
        assert_eq!(b, "b");
        q.delete(&lease_b);
        let (a, lease_a) = q.receive().unwrap();
        assert_eq!(a, "a");
        q.delete(&lease_a);
        assert!(q.receive().is_none());
    }

    #[test]
    fn purge_prefix_drains_visible_and_leased() {
        let q = StrictQueue::new(Duration::from_secs(10));
        q.send("1|a", 5);
        q.send("1|b", 0);
        q.send("2|a", 0);
        // Lease the highest-priority message of the doomed job.
        let (body, lease) = q.receive().unwrap();
        assert_eq!(body, "1|a");
        assert_eq!(q.purge_prefix("1|"), 2, "leased + visible both purged");
        assert_eq!(q.len(), 1);
        assert!(!q.delete(&lease), "lease on a purged message is stale");
        assert!(!q.renew(&lease));
        let (body, lease) = q.receive().unwrap();
        assert_eq!(body, "2|a", "other namespaces untouched");
        assert!(q.delete(&lease));
        assert_eq!(q.purge_prefix("1|"), 0, "idempotent");
    }

    #[test]
    fn expired_lease_redelivery_via_refresh_path() {
        // After expiry the candidate heap is empty — refresh_expired
        // must re-surface the message.
        let (q, clock) = queue_with_test_clock(Duration::from_millis(100));
        q.send("t", 0);
        let _ = q.receive().unwrap(); // heap now empty, msg invisible
        assert!(q.receive().is_none());
        clock.advance(Duration::from_millis(150));
        assert!(q.receive().is_some(), "expired message must resurface");
    }
}
