//! SQS-like task queue with visibility-timeout leases.
//!
//! Guarantees modelled after the real service, exactly the ones §4.1
//! relies on:
//!
//! * **at-least-once delivery** — a message can be delivered to more
//!   than one worker (after lease expiry), never zero;
//! * **visibility timeout** — a fetched message becomes invisible for
//!   the lease duration; the holder may renew; expiry re-exposes it;
//! * **delete-after-complete** — the invariant that a task is removed
//!   only once its effects are durable lives in the *executor*, not
//!   here; the queue just provides `delete` keyed by the lease;
//! * no exactly-once, no ordering (the paper: "numpywren does not
//!   require strong guarantees … at-least-once is enough").
//!
//! Time is injectable (a [`Clock`]) so fault-tolerance tests can expire
//! leases deterministically and the simulator can reuse the semantics.
//!
//! §Perf note: `receive` pops a visible-candidate max-heap (O(log n))
//! instead of scanning the message map — the map scan serialized
//! workers behind the queue mutex at high task rates (see
//! EXPERIMENTS.md §Perf). Lease expiry re-feeds the heap lazily on the
//! (rare) path where the heap runs dry.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Injectable time source.
pub trait Clock: Send + Sync + 'static {
    fn now(&self) -> Duration;
}

/// Real wall-clock.
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock {
            epoch: Instant::now(),
        }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> Duration {
        self.epoch.elapsed()
    }
}

/// Manually-advanced clock for tests.
#[derive(Default)]
pub struct TestClock {
    now_ns: AtomicU64,
}

impl TestClock {
    pub fn advance(&self, d: Duration) {
        self.now_ns.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }
}

impl Clock for TestClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.now_ns.load(Ordering::SeqCst))
    }
}

/// A held lease on a message. Deleting or renewing requires the lease;
/// a stale lease (superseded by redelivery) is rejected.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Lease {
    msg_id: u64,
    receipt: u64,
}

#[derive(Debug)]
struct Message {
    body: String,
    priority: i64,
    /// Invisible until this instant (ZERO = visible).
    invisible_until: Duration,
    /// Receipt counter — bumped on every delivery; stale receipts
    /// cannot delete/renew.
    receipt: u64,
    delivery_count: u32,
}

struct QueueInner {
    messages: HashMap<u64, Message>,
    /// Max-heap of candidates believed visible: (priority, FIFO id).
    /// Entries can be stale (message leased or deleted since push) —
    /// `receive` validates against `messages` on pop.
    visible: BinaryHeap<(i64, Reverse<u64>)>,
    next_id: u64,
}

impl QueueInner {
    /// Re-feed the candidate heap with messages whose lease expired.
    /// Called only when the heap yields nothing (rare path).
    fn refresh_expired(&mut self, now: Duration) {
        for (id, m) in &self.messages {
            if m.invisible_until != Duration::ZERO && m.invisible_until <= now {
                self.visible.push((m.priority, Reverse(*id)));
            }
        }
    }

    /// Pop the best valid visible message; take a lease on it.
    fn try_receive(&mut self, now: Duration, lease_len: Duration) -> Option<(String, Lease)> {
        loop {
            let (_, Reverse(id)) = match self.visible.pop() {
                Some(x) => x,
                None => {
                    // Heap dry: maybe leases expired — refresh once.
                    self.refresh_expired(now);
                    self.visible.pop()?
                }
            };
            let Some(m) = self.messages.get_mut(&id) else {
                continue; // deleted since pushed — stale entry
            };
            if m.invisible_until > now && m.invisible_until != Duration::ZERO {
                continue; // leased since pushed — stale entry
            }
            m.invisible_until = now + lease_len;
            m.receipt += 1;
            m.delivery_count += 1;
            return Some((
                m.body.clone(),
                Lease {
                    msg_id: id,
                    receipt: m.receipt,
                },
            ));
        }
    }
}

/// The queue. Clone-shared.
#[derive(Clone)]
pub struct TaskQueue {
    inner: Arc<(Mutex<QueueInner>, Condvar)>,
    clock: Arc<dyn Clock>,
    default_lease: Duration,
}

impl TaskQueue {
    pub fn new(default_lease: Duration) -> Self {
        Self::with_clock(default_lease, Arc::new(WallClock::new()))
    }

    pub fn with_clock(default_lease: Duration, clock: Arc<dyn Clock>) -> Self {
        TaskQueue {
            inner: Arc::new((
                Mutex::new(QueueInner {
                    messages: HashMap::new(),
                    visible: BinaryHeap::new(),
                    next_id: 1,
                }),
                Condvar::new(),
            )),
            clock,
            default_lease,
        }
    }

    /// Enqueue a message (highest `priority` delivered first among
    /// visible messages; FIFO within a priority).
    pub fn send(&self, body: &str, priority: i64) {
        let (lock, cv) = &*self.inner;
        let mut q = lock.lock().unwrap();
        let id = q.next_id;
        q.next_id += 1;
        q.messages.insert(
            id,
            Message {
                body: body.to_string(),
                priority,
                invisible_until: Duration::ZERO,
                receipt: 0,
                delivery_count: 0,
            },
        );
        q.visible.push((priority, Reverse(id)));
        cv.notify_one();
    }

    /// Try to receive the highest-priority visible message; takes a
    /// lease for `default_lease`. Non-blocking.
    pub fn receive(&self) -> Option<(String, Lease)> {
        let now = self.clock.now();
        let (lock, _) = &*self.inner;
        lock.lock().unwrap().try_receive(now, self.default_lease)
    }

    /// Blocking receive with timeout. Returns `None` on timeout. The
    /// wait and the visibility check share one lock acquisition, so a
    /// concurrent `send`'s notification cannot be lost.
    pub fn receive_timeout(&self, timeout: Duration) -> Option<(String, Lease)> {
        let deadline = Instant::now() + timeout;
        let (lock, cv) = &*self.inner;
        let mut q = lock.lock().unwrap();
        loop {
            if let Some(x) = q.try_receive(self.clock.now(), self.default_lease) {
                return Some(x);
            }
            let remaining = deadline.checked_duration_since(Instant::now())?;
            // Cap the park: lease expiry does not notify, so re-check
            // periodically.
            let (guard, _) = cv
                .wait_timeout(q, remaining.min(Duration::from_millis(10)))
                .unwrap();
            q = guard;
        }
    }

    /// Renew the lease for another `default_lease` from now. Fails if
    /// the lease is stale (message redelivered or deleted).
    pub fn renew(&self, lease: &Lease) -> bool {
        let now = self.clock.now();
        let (lock, _) = &*self.inner;
        let mut q = lock.lock().unwrap();
        match q.messages.get_mut(&lease.msg_id) {
            Some(m) if m.receipt == lease.receipt => {
                m.invisible_until = now + self.default_lease;
                true
            }
            _ => false,
        }
    }

    /// Delete the message — only valid while holding the current lease
    /// (the §4.1 invariant: delete happens only after the task's
    /// effects are durable).
    pub fn delete(&self, lease: &Lease) -> bool {
        let (lock, _) = &*self.inner;
        let mut q = lock.lock().unwrap();
        match q.messages.get(&lease.msg_id) {
            Some(m) if m.receipt == lease.receipt => {
                q.messages.remove(&lease.msg_id);
                true
            }
            _ => false,
        }
    }

    /// Number of messages (visible + invisible) — the provisioner's
    /// "pending tasks" signal.
    pub fn len(&self) -> usize {
        self.inner.0.lock().unwrap().messages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of currently-visible messages.
    pub fn visible_len(&self) -> usize {
        let now = self.clock.now();
        self.inner
            .0
            .lock()
            .unwrap()
            .messages
            .values()
            .filter(|m| m.invisible_until == Duration::ZERO || m.invisible_until <= now)
            .count()
    }

    /// How many times the message body has been delivered (testing aid;
    /// at-least-once shows up as counts > 1).
    pub fn delivery_count(&self, body: &str) -> u32 {
        self.inner
            .0
            .lock()
            .unwrap()
            .messages
            .values()
            .find(|m| m.body == body)
            .map_or(0, |m| m.delivery_count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn queue_with_test_clock(lease: Duration) -> (TaskQueue, Arc<TestClock>) {
        let clock = Arc::new(TestClock::default());
        (
            TaskQueue::with_clock(lease, clock.clone() as Arc<dyn Clock>),
            clock,
        )
    }

    #[test]
    fn send_receive_delete() {
        let q = TaskQueue::new(Duration::from_secs(10));
        q.send("t1", 0);
        let (body, lease) = q.receive().unwrap();
        assert_eq!(body, "t1");
        assert!(q.receive().is_none(), "invisible while leased");
        assert!(q.delete(&lease));
        assert!(q.is_empty());
    }

    #[test]
    fn priority_order() {
        let q = TaskQueue::new(Duration::from_secs(10));
        q.send("low", 1);
        q.send("high", 5);
        q.send("mid", 3);
        assert_eq!(q.receive().unwrap().0, "high");
        assert_eq!(q.receive().unwrap().0, "mid");
        assert_eq!(q.receive().unwrap().0, "low");
    }

    #[test]
    fn fifo_within_priority() {
        let q = TaskQueue::new(Duration::from_secs(10));
        q.send("first", 0);
        q.send("second", 0);
        assert_eq!(q.receive().unwrap().0, "first");
        assert_eq!(q.receive().unwrap().0, "second");
    }

    #[test]
    fn lease_expiry_redelivers() {
        let (q, clock) = queue_with_test_clock(Duration::from_secs(10));
        q.send("t", 0);
        let (_, lease1) = q.receive().unwrap();
        assert!(q.receive().is_none());
        clock.advance(Duration::from_secs(11));
        // Lease expired → visible again (at-least-once).
        let (_, lease2) = q.receive().unwrap();
        assert_eq!(q.delivery_count("t"), 2);
        // Stale lease can neither renew nor delete.
        assert!(!q.renew(&lease1));
        assert!(!q.delete(&lease1));
        // Fresh lease works.
        assert!(q.delete(&lease2));
    }

    #[test]
    fn renewal_keeps_invisible() {
        let (q, clock) = queue_with_test_clock(Duration::from_secs(10));
        q.send("t", 0);
        let (_, lease) = q.receive().unwrap();
        clock.advance(Duration::from_secs(8));
        assert!(q.renew(&lease));
        clock.advance(Duration::from_secs(8));
        // 16s since receive but renewed at 8s → still invisible.
        assert!(q.receive().is_none());
        clock.advance(Duration::from_secs(3));
        assert!(q.receive().is_some());
    }

    #[test]
    fn delete_only_once_effects_durable_invariant() {
        // The queue-side mechanics of §4.1: a worker that dies after
        // completing the work but before delete → message redelivered;
        // second worker's delete succeeds.
        let (q, clock) = queue_with_test_clock(Duration::from_secs(5));
        q.send("task", 0);
        let (_, dead_lease) = q.receive().unwrap();
        drop(dead_lease); // worker crashed without deleting
        clock.advance(Duration::from_secs(6));
        let (_, lease) = q.receive().unwrap();
        assert!(q.delete(&lease));
        assert!(q.is_empty());
    }

    #[test]
    fn receive_timeout_blocks_until_send() {
        let q = TaskQueue::new(Duration::from_secs(10));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.receive_timeout(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(30));
        q.send("x", 0);
        let got = h.join().unwrap();
        assert_eq!(got.unwrap().0, "x");
    }

    #[test]
    fn receive_timeout_times_out() {
        let q = TaskQueue::new(Duration::from_secs(10));
        assert!(q.receive_timeout(Duration::from_millis(30)).is_none());
    }

    #[test]
    fn concurrent_receivers_each_get_distinct_messages() {
        let q = TaskQueue::new(Duration::from_secs(30));
        for i in 0..64 {
            q.send(&format!("m{i}"), 0);
        }
        let mut handles = Vec::new();
        for _ in 0..8 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some((body, lease)) = q.receive() {
                    got.push(body);
                    q.delete(&lease);
                }
                got
            }));
        }
        let mut all: Vec<String> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 64, "each message delivered exactly once here");
    }

    #[test]
    fn stale_heap_entries_skipped() {
        // Re-sent priorities + deletes leave stale heap entries; the
        // queue must never deliver a deleted message.
        let q = TaskQueue::new(Duration::from_secs(10));
        q.send("a", 1);
        q.send("b", 2);
        let (b, lease_b) = q.receive().unwrap();
        assert_eq!(b, "b");
        q.delete(&lease_b);
        let (a, lease_a) = q.receive().unwrap();
        assert_eq!(a, "a");
        q.delete(&lease_a);
        assert!(q.receive().is_none());
    }

    #[test]
    fn expired_lease_redelivery_via_refresh_path() {
        // After expiry the candidate heap is empty — refresh_expired
        // must re-surface the message.
        let (q, clock) = queue_with_test_clock(Duration::from_millis(100));
        q.send("t", 0);
        let _ = q.receive().unwrap(); // heap now empty, msg invisible
        assert!(q.receive().is_none());
        clock.advance(Duration::from_millis(150));
        assert!(q.receive().is_some(), "expired message must resurface");
    }
}
