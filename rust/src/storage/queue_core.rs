//! The visibility-timeout message store shared by the queue backends.
//!
//! One `QueueCore` is a map of messages plus a max-heap of
//! visible-candidate entries: the strict backend wraps a single core
//! in one mutex; the sharded backend holds one core per shard. Message
//! ids are assigned by the *caller* so the sharded backend can hand
//! out globally-unique ids (the FIFO-within-priority tiebreak and the
//! shard-routing key for leases).
//!
//! §Perf note: `try_receive` pops the candidate heap (O(log n))
//! instead of scanning the message map — the map scan serialized
//! workers behind the queue lock at high task rates (see
//! EXPERIMENTS.md §Perf). Lease expiry re-feeds the heap lazily on the
//! (rare) path where the heap runs dry.

use crate::storage::traits::{ClaimWeights, Lease};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::time::Duration;

#[derive(Debug)]
struct Message {
    body: String,
    priority: i64,
    /// Invisible until this instant (ZERO = visible).
    invisible_until: Duration,
    /// Receipt counter — bumped on every delivery; stale receipts
    /// cannot delete/renew.
    receipt: u64,
    delivery_count: u32,
    /// Soft locality hint: the worker believed to hold this task's
    /// input tiles locally. Advisory only — see `try_receive_for`.
    hint: Option<u64>,
    /// When the hint was recorded (queue-clock time); hints age out
    /// after the caller's staleness bound so a dead hinted worker
    /// never pins a message.
    hinted_at: Duration,
}

/// The mechanics of one (shard of a) queue. Not thread-safe — callers
/// hold a lock around it.
#[derive(Default)]
pub(crate) struct QueueCore {
    messages: HashMap<u64, Message>,
    /// Max-heap of candidates believed visible: (priority, FIFO id).
    /// Entries can be stale (message leased or deleted since push) —
    /// `try_receive` validates against `messages` on pop.
    visible: BinaryHeap<(i64, Reverse<u64>)>,
}

impl QueueCore {
    /// Insert a message under a caller-assigned unique id.
    pub(crate) fn insert(&mut self, id: u64, body: &str, priority: i64) {
        self.insert_hinted(id, body, priority, None, Duration::ZERO);
    }

    /// [`QueueCore::insert`] with an optional locality hint, stamped
    /// with the enqueue time `now` so receives can age the hint out.
    pub(crate) fn insert_hinted(
        &mut self,
        id: u64,
        body: &str,
        priority: i64,
        hint: Option<u64>,
        now: Duration,
    ) {
        self.messages.insert(
            id,
            Message {
                body: body.to_string(),
                priority,
                invisible_until: Duration::ZERO,
                receipt: 0,
                delivery_count: 0,
                hint,
                hinted_at: now,
            },
        );
        self.visible.push((priority, Reverse(id)));
    }

    /// Re-feed the candidate heap with messages whose lease expired.
    /// Called only when the heap yields nothing (rare path).
    fn refresh_expired(&mut self, now: Duration) {
        for (id, m) in &self.messages {
            if m.invisible_until != Duration::ZERO && m.invisible_until <= now {
                self.visible.push((m.priority, Reverse(*id)));
            }
        }
    }

    /// Pop the best valid visible message; take a lease on it.
    pub(crate) fn try_receive(
        &mut self,
        now: Duration,
        lease_len: Duration,
    ) -> Option<(String, Lease)> {
        loop {
            let (_, Reverse(id)) = match self.visible.pop() {
                Some(x) => x,
                None => {
                    // Heap dry: maybe leases expired — refresh once.
                    self.refresh_expired(now);
                    self.visible.pop()?
                }
            };
            let Some(m) = self.messages.get(&id) else {
                continue; // deleted since pushed — stale entry
            };
            if m.invisible_until > now && m.invisible_until != Duration::ZERO {
                continue; // leased since pushed — stale entry
            }
            return Some(self.lease(id, now, lease_len));
        }
    }

    /// [`QueueCore::try_receive`] with affinity steering for `claimer`
    /// and optional per-job fair-share weighting.
    ///
    /// Within the **equal-top-priority group** only, a message hinted
    /// at a *different* worker (and whose hint is younger than
    /// `staleness`) is deferred in favor of the next candidate without
    /// such a hint. If the entire group is hinted elsewhere, the
    /// FIFO-best deferred message is delivered anyway — a receive
    /// never comes back empty while a visible message exists, so
    /// steering delays a message by at most the staleness window and
    /// can never starve it. A lower-priority message is never taken
    /// ahead of a deferred higher-priority one: steering bends FIFO
    /// within one priority, nothing more.
    ///
    /// When `weights` carries an active fair-share map (two or more
    /// competing jobs), the whole equal-top-priority group is scanned
    /// and the unsteered candidate whose job has the **highest claim
    /// weight** wins; replacement is strict (`>`), so equal weights
    /// preserve exact FIFO and a `None`/inactive map is byte-identical
    /// to the early-stopping unweighted walk. Weighting, like
    /// steering, never crosses a priority boundary.
    pub(crate) fn try_receive_for(
        &mut self,
        now: Duration,
        lease_len: Duration,
        claimer: u64,
        staleness: Duration,
        weights: Option<&ClaimWeights>,
    ) -> Option<(String, Lease)> {
        let weights = weights.filter(|w| w.active());
        let mut deferred: Vec<(i64, Reverse<u64>)> = Vec::new();
        // Candidates popped but not chosen (weighted scan only) — they
        // go back on the heap before returning.
        let mut passed: Vec<(i64, Reverse<u64>)> = Vec::new();
        let mut chosen: Option<(u64, f64)> = None;
        let mut group: Option<i64> = None;
        loop {
            let (prio, Reverse(id)) = match self.visible.pop() {
                Some(x) => x,
                None => {
                    // Heap dry: maybe leases expired — refresh once.
                    self.refresh_expired(now);
                    match self.visible.pop() {
                        Some(x) => x,
                        None => break,
                    }
                }
            };
            let Some(m) = self.messages.get(&id) else {
                continue; // deleted since pushed — stale entry
            };
            if m.invisible_until > now && m.invisible_until != Duration::ZERO {
                continue; // leased since pushed — stale entry
            }
            if let Some(g) = group {
                if prio < g {
                    // The equal-priority group is exhausted; taking
                    // this one would invert priority. Restore it and
                    // fall back to the best seen so far.
                    self.visible.push((prio, Reverse(id)));
                    break;
                }
            }
            group = group.or(Some(prio));
            let steered_away = match m.hint {
                Some(h) => h != claimer && now.saturating_sub(m.hinted_at) < staleness,
                None => false,
            };
            if steered_away {
                deferred.push((prio, Reverse(id)));
                continue;
            }
            match weights {
                None => {
                    chosen = Some((id, 1.0));
                    break;
                }
                Some(w) => {
                    let wt = w.weight_of_body(&m.body);
                    match chosen {
                        Some((best_id, best_wt)) if wt > best_wt => {
                            passed.push((prio, Reverse(best_id)));
                            chosen = Some((id, wt));
                        }
                        Some(_) => passed.push((prio, Reverse(id))),
                        None => chosen = Some((id, wt)),
                    }
                }
            }
        }
        let mut deferred = deferred.into_iter();
        let id = match chosen {
            Some((id, _)) => id,
            // Whole group steered elsewhere → take the FIFO-best
            // anyway (no starvation); `None` only when nothing is
            // visible at all.
            None => deferred.next()?.1 .0,
        };
        for entry in deferred.chain(passed) {
            self.visible.push(entry);
        }
        Some(self.lease(id, now, lease_len))
    }

    /// Take the lease on a validated visible candidate.
    fn lease(&mut self, id: u64, now: Duration, lease_len: Duration) -> (String, Lease) {
        let m = self.messages.get_mut(&id).expect("validated candidate");
        m.invisible_until = now + lease_len;
        m.receipt += 1;
        m.delivery_count += 1;
        (
            m.body.clone(),
            Lease {
                msg_id: id,
                receipt: m.receipt,
            },
        )
    }

    /// Extend the lease to `now + lease_len` iff it is current.
    pub(crate) fn renew(&mut self, lease: &Lease, now: Duration, lease_len: Duration) -> bool {
        match self.messages.get_mut(&lease.msg_id) {
            Some(m) if m.receipt == lease.receipt => {
                m.invisible_until = now + lease_len;
                true
            }
            _ => false,
        }
    }

    /// Remove the message iff the lease is current.
    pub(crate) fn delete(&mut self, lease: &Lease) -> bool {
        match self.messages.get(&lease.msg_id) {
            Some(m) if m.receipt == lease.receipt => {
                self.messages.remove(&lease.msg_id);
                true
            }
            _ => false,
        }
    }

    pub(crate) fn len(&self) -> usize {
        self.messages.len()
    }

    pub(crate) fn visible_len(&self, now: Duration) -> usize {
        self.messages
            .values()
            .filter(|m| m.invisible_until == Duration::ZERO || m.invisible_until <= now)
            .count()
    }

    /// Remove every message whose body starts with `prefix`, visible
    /// or leased; returns the count. Held leases on purged messages go
    /// stale (their renew/delete find no message); stale heap entries
    /// are already skipped by `try_receive`'s validation pop.
    pub(crate) fn purge_prefix(&mut self, prefix: &str) -> usize {
        let before = self.messages.len();
        self.messages.retain(|_, m| !m.body.starts_with(prefix));
        before - self.messages.len()
    }

    pub(crate) fn delivery_count(&self, body: &str) -> Option<u32> {
        self.messages
            .values()
            .find(|m| m.body == body)
            .map(|m| m.delivery_count)
    }
}
