//! The visibility-timeout message store shared by the queue backends.
//!
//! One `QueueCore` is a map of messages plus a max-heap of
//! visible-candidate entries: the strict backend wraps a single core
//! in one mutex; the sharded backend holds one core per shard. Message
//! ids are assigned by the *caller* so the sharded backend can hand
//! out globally-unique ids (the FIFO-within-priority tiebreak and the
//! shard-routing key for leases).
//!
//! §Perf note: `try_receive` pops the candidate heap (O(log n))
//! instead of scanning the message map — the map scan serialized
//! workers behind the queue lock at high task rates (see
//! EXPERIMENTS.md §Perf). Lease expiry re-feeds the heap lazily on the
//! (rare) path where the heap runs dry.

use crate::storage::traits::Lease;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::time::Duration;

#[derive(Debug)]
struct Message {
    body: String,
    priority: i64,
    /// Invisible until this instant (ZERO = visible).
    invisible_until: Duration,
    /// Receipt counter — bumped on every delivery; stale receipts
    /// cannot delete/renew.
    receipt: u64,
    delivery_count: u32,
}

/// The mechanics of one (shard of a) queue. Not thread-safe — callers
/// hold a lock around it.
#[derive(Default)]
pub(crate) struct QueueCore {
    messages: HashMap<u64, Message>,
    /// Max-heap of candidates believed visible: (priority, FIFO id).
    /// Entries can be stale (message leased or deleted since push) —
    /// `try_receive` validates against `messages` on pop.
    visible: BinaryHeap<(i64, Reverse<u64>)>,
}

impl QueueCore {
    /// Insert a message under a caller-assigned unique id.
    pub(crate) fn insert(&mut self, id: u64, body: &str, priority: i64) {
        self.messages.insert(
            id,
            Message {
                body: body.to_string(),
                priority,
                invisible_until: Duration::ZERO,
                receipt: 0,
                delivery_count: 0,
            },
        );
        self.visible.push((priority, Reverse(id)));
    }

    /// Re-feed the candidate heap with messages whose lease expired.
    /// Called only when the heap yields nothing (rare path).
    fn refresh_expired(&mut self, now: Duration) {
        for (id, m) in &self.messages {
            if m.invisible_until != Duration::ZERO && m.invisible_until <= now {
                self.visible.push((m.priority, Reverse(*id)));
            }
        }
    }

    /// Pop the best valid visible message; take a lease on it.
    pub(crate) fn try_receive(
        &mut self,
        now: Duration,
        lease_len: Duration,
    ) -> Option<(String, Lease)> {
        loop {
            let (_, Reverse(id)) = match self.visible.pop() {
                Some(x) => x,
                None => {
                    // Heap dry: maybe leases expired — refresh once.
                    self.refresh_expired(now);
                    self.visible.pop()?
                }
            };
            let Some(m) = self.messages.get_mut(&id) else {
                continue; // deleted since pushed — stale entry
            };
            if m.invisible_until > now && m.invisible_until != Duration::ZERO {
                continue; // leased since pushed — stale entry
            }
            m.invisible_until = now + lease_len;
            m.receipt += 1;
            m.delivery_count += 1;
            return Some((
                m.body.clone(),
                Lease {
                    msg_id: id,
                    receipt: m.receipt,
                },
            ));
        }
    }

    /// Extend the lease to `now + lease_len` iff it is current.
    pub(crate) fn renew(&mut self, lease: &Lease, now: Duration, lease_len: Duration) -> bool {
        match self.messages.get_mut(&lease.msg_id) {
            Some(m) if m.receipt == lease.receipt => {
                m.invisible_until = now + lease_len;
                true
            }
            _ => false,
        }
    }

    /// Remove the message iff the lease is current.
    pub(crate) fn delete(&mut self, lease: &Lease) -> bool {
        match self.messages.get(&lease.msg_id) {
            Some(m) if m.receipt == lease.receipt => {
                self.messages.remove(&lease.msg_id);
                true
            }
            _ => false,
        }
    }

    pub(crate) fn len(&self) -> usize {
        self.messages.len()
    }

    pub(crate) fn visible_len(&self, now: Duration) -> usize {
        self.messages
            .values()
            .filter(|m| m.invisible_until == Duration::ZERO || m.invisible_until <= now)
            .count()
    }

    /// Remove every message whose body starts with `prefix`, visible
    /// or leased; returns the count. Held leases on purged messages go
    /// stale (their renew/delete find no message); stale heap entries
    /// are already skipped by `try_receive`'s validation pop.
    pub(crate) fn purge_prefix(&mut self, prefix: &str) -> usize {
        let before = self.messages.len();
        self.messages.retain(|_, m| !m.body.starts_with(prefix));
        before - self.messages.len()
    }

    pub(crate) fn delivery_count(&self, body: &str) -> Option<u32> {
        self.messages
            .values()
            .find(|m| m.body == body)
            .map(|m| m.delivery_count)
    }
}
