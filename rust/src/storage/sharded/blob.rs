//! N-way sharded object store.
//!
//! Keys hash onto independent `RwLock<HashMap>` shards, so concurrent
//! tile puts/gets from many workers contend only when they land on the
//! same shard (1/N of the time for uniform keys) instead of always.
//! Accounting is the same lock-free atomics as the strict backend. No
//! `strict_ssa` mode — SSA policing is the test backend's job.

use crate::linalg::matrix::Matrix;
use crate::storage::sharded::shard_of;
use crate::storage::traits::{BlobStore, PrefixAges, StoreStats, Stored, TransferAccounting};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

type Shard = RwLock<HashMap<String, Stored>>;

/// The store. Cheap to clone (Arc-shared).
#[derive(Clone)]
pub struct ShardedBlobStore {
    inner: Arc<Inner>,
}

struct Inner {
    shards: Vec<Shard>,
    accounting: TransferAccounting,
    /// Injected latency per operation (simulates S3's ~10 ms).
    latency: Duration,
}

impl ShardedBlobStore {
    pub fn new(n_shards: usize) -> Self {
        Self::with_latency(n_shards, Duration::ZERO)
    }

    /// A store that sleeps `latency` on every get/put.
    pub fn with_latency(n_shards: usize, latency: Duration) -> Self {
        let n = n_shards.max(1);
        ShardedBlobStore {
            inner: Arc::new(Inner {
                shards: (0..n).map(|_| RwLock::new(HashMap::new())).collect(),
                accounting: TransferAccounting::default(),
                latency,
            }),
        }
    }

    fn shard(&self, key: &str) -> &Shard {
        &self.inner.shards[shard_of(key, self.inner.shards.len())]
    }

    fn latency(&self) {
        if !self.inner.latency.is_zero() {
            std::thread::sleep(self.inner.latency);
        }
    }
}

impl BlobStore for ShardedBlobStore {
    fn put(&self, worker: usize, key: &str, value: Matrix) -> Result<()> {
        self.latency();
        let bytes = (value.rows() * value.cols() * 8) as u64;
        self.shard(key).write().unwrap().insert(key.to_string(), Stored::new(value));
        self.inner.accounting.record_put(worker, bytes);
        Ok(())
    }

    fn get(&self, worker: usize, key: &str) -> Result<Arc<Matrix>> {
        self.latency();
        let v = self
            .shard(key)
            .read()
            .unwrap()
            .get(key)
            .map(|s| s.tile.clone())
            .with_context(|| format!("object-store key `{key}` not found"))?;
        let bytes = (v.rows() * v.cols() * 8) as u64;
        self.inner.accounting.record_get(worker, bytes);
        Ok(v)
    }

    fn contains(&self, key: &str) -> bool {
        self.shard(key).read().unwrap().contains_key(key)
    }

    fn delete(&self, key: &str) -> Result<bool> {
        Ok(self.shard(key).write().unwrap().remove(key).is_some())
    }

    fn scan_prefix(&self, prefix: &str) -> Vec<String> {
        // Per-shard sweep in shard-index order (one read lock at a
        // time — prefix ops need no cross-shard atomicity).
        let mut keys = Vec::new();
        for shard in &self.inner.shards {
            keys.extend(
                shard
                    .read()
                    .unwrap()
                    .keys()
                    .filter(|k| k.starts_with(prefix))
                    .cloned(),
            );
        }
        keys.sort_unstable();
        keys
    }

    fn delete_prefix(&self, prefix: &str) -> usize {
        let mut removed = 0;
        for shard in &self.inner.shards {
            let mut map = shard.write().unwrap();
            let before = map.len();
            map.retain(|k, _| !k.starts_with(prefix));
            removed += before - map.len();
        }
        removed
    }

    fn prefix_age(&self, prefix: &str) -> Option<Duration> {
        // Per-shard sweep, min over the per-key ages = time since the
        // newest write anywhere under the prefix.
        let now = Instant::now();
        let mut age: Option<Duration> = None;
        for shard in &self.inner.shards {
            for (k, s) in shard.read().unwrap().iter() {
                if k.starts_with(prefix) {
                    let a = now.saturating_duration_since(s.written);
                    if age.is_none_or(|cur| a < cur) {
                        age = Some(a);
                    }
                }
            }
        }
        age
    }

    fn prefix_ages(&self, delimiter: char) -> Vec<(String, Duration)> {
        // One pass over every shard, merging per-namespace minima
        // (keys of one namespace hash across all shards).
        let mut acc = PrefixAges::new(delimiter);
        for shard in &self.inner.shards {
            for (k, s) in shard.read().unwrap().iter() {
                acc.observe(k, s.written);
            }
        }
        acc.finish()
    }

    fn len(&self) -> usize {
        self.inner
            .shards
            .iter()
            .map(|s| s.read().unwrap().len())
            .sum()
    }

    fn stats(&self) -> StoreStats {
        self.inner.accounting.stats()
    }

    fn worker_stats(&self, worker: usize) -> StoreStats {
        self.inner.accounting.worker_stats(worker)
    }

    fn known_workers(&self) -> Vec<usize> {
        self.inner.accounting.known_workers()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_across_shard_counts() {
        for n in [1usize, 4, 16] {
            let s = ShardedBlobStore::new(n);
            let mut rng = Rng::new(7);
            for i in 0..32 {
                let m = Matrix::randn(2, 2, &mut rng);
                let key = format!("T[{i},{}]", i % 5);
                s.put(0, &key, m.clone()).unwrap();
                assert_eq!(*s.get(0, &key).unwrap(), m);
                assert!(s.contains(&key));
            }
            assert_eq!(s.len(), 32);
            assert!(s.get(0, "missing").is_err());
        }
    }

    #[test]
    fn concurrent_writers_on_distinct_keys() {
        let s = ShardedBlobStore::new(8);
        let mut handles = Vec::new();
        for t in 0..16usize {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..20 {
                    let key = format!("K[{t},{i}]");
                    s.put(t, &key, Matrix::from_vec(1, 1, vec![t as f64]))
                        .unwrap();
                    assert_eq!(s.get(t, &key).unwrap()[(0, 0)], t as f64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 16 * 20);
        assert_eq!(s.known_workers().len(), 16);
    }

    #[test]
    fn delete_and_prefix_sweep_across_shards() {
        for n in [1usize, 4, 16] {
            let s = ShardedBlobStore::new(n);
            for j in 1..=2 {
                for k in 0..8 {
                    s.put(0, &format!("j{j}/T[{k}]"), Matrix::zeros(1, 1)).unwrap();
                }
            }
            let j1 = s.scan_prefix("j1/");
            assert_eq!(j1.len(), 8, "[{n} shards]");
            assert!(j1.windows(2).all(|w| w[0] < w[1]), "sorted [{n} shards]");
            assert!(s.delete("j1/T[0]").unwrap());
            assert!(!s.delete("j1/T[0]").unwrap());
            assert_eq!(s.delete_prefix("j1/"), 7, "[{n} shards]");
            assert_eq!(s.len(), 8, "[{n} shards] j2 untouched");
            assert_eq!(s.delete_prefix(""), 8, "[{n} shards] full sweep");
            assert!(s.is_empty());
        }
    }

    #[test]
    fn prefix_age_spans_shards() {
        for n in [1usize, 4, 16] {
            let s = ShardedBlobStore::new(n);
            assert_eq!(s.prefix_age("j1/"), None, "[{n} shards]");
            for k in 0..6 {
                s.put(0, &format!("j1/T[{k}]"), Matrix::zeros(1, 1)).unwrap();
            }
            std::thread::sleep(Duration::from_millis(8));
            let aged = s.prefix_age("j1/").unwrap();
            assert!(aged >= Duration::from_millis(8), "[{n} shards]");
            // Refreshing any one key rejuvenates the whole namespace.
            s.put(0, "j1/T[3]", Matrix::zeros(1, 1)).unwrap();
            assert!(s.prefix_age("j1/").unwrap() < aged, "[{n} shards]");
            // Bulk form merges per-shard minima into one sorted list.
            s.put(0, "j2/T[0]", Matrix::zeros(1, 1)).unwrap();
            let ages = s.prefix_ages('/');
            let names: Vec<&str> = ages.iter().map(|(p, _)| p.as_str()).collect();
            assert_eq!(names, vec!["j1/", "j2/"], "[{n} shards]");
            let diff = s.prefix_age("j1/").unwrap().abs_diff(ages[0].1);
            assert!(diff < Duration::from_millis(50), "[{n} shards] {diff:?}");
        }
    }

    #[test]
    fn accounting_matches_strict_semantics() {
        let s = ShardedBlobStore::new(4);
        let m = Matrix::zeros(4, 8); // 256 bytes
        s.put(3, "X[0]", m).unwrap();
        s.get(3, "X[0]").unwrap();
        s.get(4, "X[0]").unwrap();
        let t = s.stats();
        assert_eq!(t.bytes_written, 256);
        assert_eq!(t.bytes_read, 512);
        assert_eq!(t.put_ops, 1);
        assert_eq!(t.get_ops, 2);
        assert_eq!(s.worker_stats(4).bytes_read, 256);
        assert_eq!(s.worker_stats(4).bytes_written, 0);
    }
}
