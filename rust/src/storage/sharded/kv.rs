//! N-way sharded runtime state store.
//!
//! Status keys and dependency counters hash onto independent
//! `Mutex<HashMap>` shards (separate shard sets for the string KV and
//! the counters, like the strict backend's two maps). Every trait
//! operation is per-key except [`KvState::edge_decr`], which must
//! atomically mark an edge *and* decrement a counter: when the two
//! keys land on different shards, both locks are taken in shard-index
//! order — a total order, so concurrent edge_decrs cannot deadlock —
//! and the pair-update happens under both.

use crate::storage::sharded::shard_of;
use crate::storage::traits::KvState;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

type KvShard = Mutex<HashMap<String, String>>;
type CounterShard = Mutex<HashMap<String, i64>>;

/// The store. Clone-shared.
#[derive(Clone)]
pub struct ShardedKvState {
    inner: Arc<Inner>,
}

struct Inner {
    kv: Vec<KvShard>,
    counters: Vec<CounterShard>,
    ops: AtomicU64,
}

impl ShardedKvState {
    pub fn new(n_shards: usize) -> Self {
        let n = n_shards.max(1);
        ShardedKvState {
            inner: Arc::new(Inner {
                kv: (0..n).map(|_| Mutex::new(HashMap::new())).collect(),
                counters: (0..n).map(|_| Mutex::new(HashMap::new())).collect(),
                ops: AtomicU64::new(0),
            }),
        }
    }

    fn bump(&self) {
        self.inner.ops.fetch_add(1, Ordering::Relaxed);
    }

    fn kv_shard(&self, key: &str) -> &KvShard {
        &self.inner.kv[shard_of(key, self.inner.kv.len())]
    }

    fn counter_shard(&self, key: &str) -> &CounterShard {
        &self.inner.counters[shard_of(key, self.inner.counters.len())]
    }
}

/// The single-shard edge_decr step, shared by the one-lock and
/// two-lock paths.
fn edge_decr_in(
    edges: &mut HashMap<String, i64>,
    counters: &mut HashMap<String, i64>,
    edge_key: &str,
    counter_key: &str,
) -> i64 {
    if edges.contains_key(edge_key) {
        *counters.get(counter_key).unwrap_or(&0)
    } else {
        edges.insert(edge_key.to_string(), 1);
        let v = counters.entry(counter_key.to_string()).or_insert(0);
        *v -= 1;
        *v
    }
}

impl KvState for ShardedKvState {
    fn op_count(&self) -> u64 {
        self.inner.ops.load(Ordering::Relaxed)
    }

    fn get(&self, key: &str) -> Option<String> {
        self.bump();
        self.kv_shard(key).lock().unwrap().get(key).cloned()
    }

    fn set(&self, key: &str, value: &str) {
        self.bump();
        self.kv_shard(key)
            .lock()
            .unwrap()
            .insert(key.to_string(), value.to_string());
    }

    fn set_nx(&self, key: &str, value: &str) -> bool {
        self.bump();
        let mut kv = self.kv_shard(key).lock().unwrap();
        if kv.contains_key(key) {
            false
        } else {
            kv.insert(key.to_string(), value.to_string());
            true
        }
    }

    fn cas(&self, key: &str, expect: Option<&str>, value: &str) -> bool {
        self.bump();
        let mut kv = self.kv_shard(key).lock().unwrap();
        let cur = kv.get(key).map(|s| s.as_str());
        if cur == expect {
            kv.insert(key.to_string(), value.to_string());
            true
        } else {
            false
        }
    }

    fn init_counter(&self, key: &str, value: i64) -> bool {
        self.bump();
        let mut c = self.counter_shard(key).lock().unwrap();
        if c.contains_key(key) {
            false
        } else {
            c.insert(key.to_string(), value);
            true
        }
    }

    fn incr(&self, key: &str, delta: i64) -> i64 {
        self.bump();
        let mut c = self.counter_shard(key).lock().unwrap();
        let v = c.entry(key.to_string()).or_insert(0);
        *v += delta;
        *v
    }

    fn counter(&self, key: &str) -> i64 {
        self.bump();
        *self
            .counter_shard(key)
            .lock()
            .unwrap()
            .get(key)
            .unwrap_or(&0)
    }

    fn counter_exists(&self, key: &str) -> bool {
        self.counter_shard(key).lock().unwrap().contains_key(key)
    }

    fn delete(&self, key: &str) -> bool {
        self.bump();
        // Two independent per-key locks, taken one at a time — no pair
        // atomicity needed (delete is not racing edge_decr on a live
        // namespace; GC sweeps only quiescent prefixes).
        let in_kv = self.kv_shard(key).lock().unwrap().remove(key).is_some();
        let in_counters = self
            .counter_shard(key)
            .lock()
            .unwrap()
            .remove(key)
            .is_some();
        in_kv || in_counters
    }

    fn scan_prefix(&self, prefix: &str) -> Vec<String> {
        // Per-shard sweeps in shard-index order, one lock at a time —
        // the same total order every other path uses.
        let mut keys = Vec::new();
        for shard in &self.inner.kv {
            keys.extend(
                shard
                    .lock()
                    .unwrap()
                    .keys()
                    .filter(|k| k.starts_with(prefix))
                    .cloned(),
            );
        }
        for shard in &self.inner.counters {
            keys.extend(
                shard
                    .lock()
                    .unwrap()
                    .keys()
                    .filter(|k| k.starts_with(prefix))
                    .cloned(),
            );
        }
        keys.sort_unstable();
        keys.dedup();
        keys
    }

    fn delete_prefix(&self, prefix: &str) -> usize {
        self.bump();
        let mut removed = 0;
        for shard in self.inner.kv.iter().chain(self.inner.counters.iter()) {
            let mut map = shard.lock().unwrap();
            let before = map.len();
            map.retain(|k, _| !k.starts_with(prefix));
            removed += before - map.len();
        }
        removed
    }

    fn edge_decr(&self, edge_key: &str, counter_key: &str) -> i64 {
        self.bump();
        let n = self.inner.counters.len();
        let ei = shard_of(edge_key, n);
        let ci = shard_of(counter_key, n);
        if ei == ci {
            let mut shard = self.inner.counters[ei].lock().unwrap();
            // One map plays both roles, like the strict backend.
            let shard = &mut *shard;
            if shard.contains_key(edge_key) {
                *shard.get(counter_key).unwrap_or(&0)
            } else {
                shard.insert(edge_key.to_string(), 1);
                let v = shard.entry(counter_key.to_string()).or_insert(0);
                *v -= 1;
                *v
            }
        } else {
            // Two shards: lock in index order (total order → no
            // deadlock), then update both under the pair of locks.
            let (lo, hi) = (ei.min(ci), ei.max(ci));
            let mut g_lo = self.inner.counters[lo].lock().unwrap();
            let mut g_hi = self.inner.counters[hi].lock().unwrap();
            let (edges, counters) = if ei == lo {
                (&mut *g_lo, &mut *g_hi)
            } else {
                (&mut *g_hi, &mut *g_lo)
            };
            edge_decr_in(edges, counters, edge_key, counter_key)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_ops_match_strict_semantics() {
        let s = ShardedKvState::new(8);
        assert_eq!(s.get("k"), None);
        s.set("k", "v");
        assert_eq!(s.get("k").as_deref(), Some("v"));
        assert!(s.set_nx("nx", "1"));
        assert!(!s.set_nx("nx", "2"));
        assert!(s.cas("t", None, "pending"));
        assert!(!s.cas("t", None, "pending"));
        assert!(s.cas("t", Some("pending"), "completed"));
        assert!(s.init_counter("c", 5));
        assert!(!s.init_counter("c", 99));
        assert_eq!(s.counter("c"), 5);
        assert_eq!(s.incr("c", 2), 7);
        assert_eq!(s.decr("c"), 6);
        assert!(s.counter_exists("c"));
        assert!(!s.counter_exists("nope"));
        assert!(s.op_count() > 0);
    }

    #[test]
    fn edge_decr_idempotent_across_shards() {
        // Many (edge, counter) pairs so both the same-shard and the
        // cross-shard paths get exercised at every shard count.
        for n in [1usize, 2, 16] {
            let s = ShardedKvState::new(n);
            for c in 0..8 {
                let ck = format!("deps:{c}");
                s.init_counter(&ck, 3);
                for p in 0..3 {
                    let ek = format!("edge:{p}:{c}");
                    let first = s.edge_decr(&ek, &ck);
                    assert_eq!(first, 2 - p);
                    // Re-execution: value re-observed, no double decrement.
                    assert_eq!(s.edge_decr(&ek, &ck), first);
                }
                assert_eq!(s.counter(&ck), 0);
            }
        }
    }

    #[test]
    fn delete_and_prefix_sweep_across_shards() {
        for n in [1usize, 2, 16] {
            let s = ShardedKvState::new(n);
            for j in 1..=2 {
                s.set(&format!("j{j}/status:a"), "completed");
                s.init_counter(&format!("j{j}/deps:b"), 2);
                s.edge_decr(&format!("j{j}/edge:a:b"), &format!("j{j}/deps:b"));
            }
            assert_eq!(s.scan_prefix("j1/").len(), 3, "[{n} shards]");
            assert!(s.delete("j1/status:a"), "[{n} shards]");
            assert!(!s.delete("j1/status:a"), "[{n} shards]");
            assert_eq!(s.delete_prefix("j1/"), 2, "[{n} shards] deps + edge");
            assert_eq!(s.delete_prefix("j1/"), 0, "[{n} shards]");
            assert!(s.counter_exists("j2/deps:b"), "[{n} shards]");
            assert_eq!(s.counter("j2/deps:b"), 1, "[{n} shards]");
        }
    }

    #[test]
    fn edge_decr_concurrent_no_deadlock_and_exact() {
        // Hammer cross-shard pairs from many threads; the counter sum
        // must come out exact and nothing may deadlock.
        let s = ShardedKvState::new(4);
        let n_parents = 16;
        s.init_counter("deps:hot", n_parents);
        let mut handles = Vec::new();
        for p in 0..n_parents {
            for _dup in 0..3 {
                let s = s.clone();
                handles.push(std::thread::spawn(move || {
                    s.edge_decr(&format!("edge:{p}:hot"), "deps:hot") == 0
                }));
            }
        }
        let zeros: usize = handles
            .into_iter()
            .map(|h| h.join().unwrap() as usize)
            .sum();
        assert!(zeros >= 1);
        assert_eq!(s.counter("deps:hot"), 0);
    }
}
