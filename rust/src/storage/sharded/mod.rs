//! The sharded substrate family — the high-concurrency default.
//!
//! The real services the paper builds on scale because they shard
//! internally: S3 partitions by key prefix, SQS by message, Redis
//! Cluster by hash slot. The single-lock `strict` backends serialize
//! every worker behind one mutex exactly where the cloud would not;
//! this family restores the sharding:
//!
//! * [`ShardedBlobStore`] — N-way key-hash shards, each its own
//!   `RwLock` map; transfer accounting stays lock-free atomics.
//! * [`ShardedQueue`] — per-shard priority heaps with work-stealing
//!   receive; a global sequence number keeps FIFO-within-priority
//!   deterministic per shard (and exactly FIFO with one shard).
//! * [`ShardedKvState`] — N-way hash-sharded KV and counter maps;
//!   the two-key `edge_decr` primitive takes both shard locks in
//!   index order, so it stays atomic and deadlock-free.
//!
//! All three implement the `storage::traits` contracts and pass the
//! same conformance suite as the strict family
//! (`tests/substrate_conformance.rs`); `perf_substrate_contention`
//! measures the contention win.

mod blob;
mod kv;
mod queue;

pub use blob::ShardedBlobStore;
pub use kv::ShardedKvState;
pub use queue::ShardedQueue;

/// FNV-1a — fast, deterministic key→shard routing (no per-op hasher
/// allocation, no RandomState seeding differences across handles).
pub(crate) fn shard_of(key: &str, n_shards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % n_shards as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_of_in_range_and_deterministic() {
        for n in [1usize, 2, 7, 16, 64] {
            for key in ["", "a", "deps:2@i=0,j=1", "S[0,3,1]"] {
                let s = shard_of(key, n);
                assert!(s < n);
                assert_eq!(s, shard_of(key, n), "deterministic");
            }
        }
    }

    #[test]
    fn shard_of_spreads_keys() {
        // Not a statistical test — just confirm typical key families
        // don't all collapse onto one shard.
        let n = 16;
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..64 {
            seen.insert(shard_of(&format!("deps:2@i={i},j={}", i * 7), n));
        }
        assert!(seen.len() > n / 2, "only {} shards hit", seen.len());
    }
}
