//! N-way sharded task queue with work-stealing receive.
//!
//! Messages are assigned globally-unique ids from one atomic counter
//! and placed on shard `id % N` (round-robin by construction), so
//! `send` and `delete`/`renew` (routed by the lease's id) each touch
//! exactly one shard lock. `receive` starts at a rotating shard and
//! steals from the others until it finds a visible message, so
//! receivers spread across shards instead of convoying on one mutex.
//!
//! Ordering contract: *within a shard* delivery is highest-priority
//! first, FIFO within a priority (the global sequence number is the
//! heap tiebreak); *across shards* ordering is best-effort — exactly
//! the paper's position that numpywren needs at-least-once delivery,
//! not ordering, from SQS. With `n_shards == 1` the ordering is
//! globally exact (that configuration is what the ordering conformance
//! tests pin down). At-least-once, visibility timeouts, and lease
//! staleness behave identically to the strict backend — the per-shard
//! mechanics are the shared crate-private `QueueCore`.
//!
//! Affinity: `send_hinted` stamps a message with a soft locality hint
//! (the worker holding its input tiles in the local tile cache — see
//! [`crate::storage::cache`]), and `receive_for` steers hinted
//! messages toward that worker *within the equal-top-priority group of
//! one shard only*. The hint ages out after a bounded staleness window
//! ([`DEFAULT_HINT_STALENESS`]), and a receive falls back to the
//! FIFO-best steered message rather than come back empty — so priority
//! order is never inverted, no worker idles while work is visible, and
//! a dead hinted worker delays a message by at most the window.
//!
//! Blocking receives park on an epoch counter + condvar: `send` bumps
//! an atomic epoch, and a receiver only sleeps if the epoch has not
//! moved since it scanned the shards — no lost wakeups (the receiver
//! re-checks the epoch under the park mutex, and a sender can only
//! deliver its notify after the receiver has atomically released that
//! mutex into the wait). The send path touches the park mutex only
//! when a receiver is actually parked (`waiters > 0`), so sends stay
//! shard-local under load. The park is capped (10 ms) because lease
//! *expiry* makes messages visible without bumping the epoch.

use crate::storage::clock::{Clock, WallClock};
use crate::storage::queue_core::QueueCore;
use crate::storage::traits::{ClaimWeights, Lease, Queue};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// How long a locality hint may steer a message away from
/// non-preferred workers before it is considered stale (see
/// [`Queue::receive_for`]): comfortably above the 10 ms receive-park
/// cap, so a hinted worker that is merely mid-poll gets a claim
/// window, yet small enough that a slow or dead hinted worker delays
/// a message imperceptibly.
pub const DEFAULT_HINT_STALENESS: Duration = Duration::from_millis(30);

/// The queue. Clone-shared.
#[derive(Clone)]
pub struct ShardedQueue {
    inner: Arc<Inner>,
    clock: Arc<dyn Clock>,
    default_lease: Duration,
    hint_staleness: Duration,
}

struct Inner {
    shards: Vec<Mutex<QueueCore>>,
    /// Global id source: FIFO tiebreak + shard routing key.
    next_id: AtomicU64,
    /// Rotating start shard for work-stealing receives.
    rr: AtomicUsize,
    /// Send epoch — bumped on every send; blocking receivers park
    /// only while it stands still.
    epoch: AtomicU64,
    /// Number of receivers in the park protocol right now.
    waiters: AtomicUsize,
    park: Mutex<()>,
    cv: Condvar,
    /// Shared per-job fair-share weights ([`Queue::set_claim_weights`]);
    /// `None` (and single-job maps) keep the unweighted claim path.
    weights: RwLock<Option<Arc<ClaimWeights>>>,
}

impl ShardedQueue {
    pub fn new(n_shards: usize, default_lease: Duration) -> Self {
        Self::with_clock(n_shards, default_lease, Arc::new(WallClock::new()))
    }

    pub fn with_clock(n_shards: usize, default_lease: Duration, clock: Arc<dyn Clock>) -> Self {
        let n = n_shards.max(1);
        ShardedQueue {
            inner: Arc::new(Inner {
                shards: (0..n).map(|_| Mutex::new(QueueCore::default())).collect(),
                next_id: AtomicU64::new(1),
                rr: AtomicUsize::new(0),
                epoch: AtomicU64::new(0),
                waiters: AtomicUsize::new(0),
                park: Mutex::new(()),
                cv: Condvar::new(),
                weights: RwLock::new(None),
            }),
            clock,
            default_lease,
            hint_staleness: DEFAULT_HINT_STALENESS,
        }
    }

    /// Override the hint staleness bound (tests use a `TestClock`-sized
    /// window; [`DEFAULT_HINT_STALENESS`] otherwise).
    pub fn with_hint_staleness(mut self, staleness: Duration) -> Self {
        self.hint_staleness = staleness;
        self
    }

    fn shard_for_id(&self, id: u64) -> &Mutex<QueueCore> {
        let n = self.inner.shards.len();
        &self.inner.shards[(id % n as u64) as usize]
    }

    /// One work-stealing pass over the shards; with a claimer, each
    /// shard applies affinity steering and fair-share weighting.
    fn scan(&self, claimer: Option<u64>) -> Option<(String, Lease)> {
        let now = self.clock.now();
        let n = self.inner.shards.len();
        let start = self.inner.rr.fetch_add(1, Ordering::Relaxed) % n;
        let weights = self.inner.weights.read().unwrap().clone();
        for k in 0..n {
            let mut shard = self.inner.shards[(start + k) % n].lock().unwrap();
            let got = match claimer {
                Some(w) => shard.try_receive_for(
                    now,
                    self.default_lease,
                    w,
                    self.hint_staleness,
                    weights.as_deref(),
                ),
                None => shard.try_receive(now, self.default_lease),
            };
            if got.is_some() {
                return got;
            }
        }
        None
    }

    /// The epoch-parked blocking receive behind both
    /// [`Queue::receive_timeout`] and [`Queue::receive_timeout_for`].
    fn scan_timeout(&self, claimer: Option<u64>, timeout: Duration) -> Option<(String, Lease)> {
        let deadline = Instant::now() + timeout;
        loop {
            let seen = self.inner.epoch.load(Ordering::SeqCst);
            if let Some(x) = self.scan(claimer) {
                return Some(x);
            }
            let Some(remaining) = deadline.checked_duration_since(Instant::now()) else {
                return None;
            };
            self.inner.waiters.fetch_add(1, Ordering::SeqCst);
            let guard = self.inner.park.lock().unwrap();
            if self.inner.epoch.load(Ordering::SeqCst) == seen {
                // Nothing arrived since the scan; park (capped — lease
                // expiry does not bump the epoch). A send after the
                // re-check must take the park mutex to notify, which it
                // cannot do until `wait_timeout` has released it — so
                // the wakeup cannot be lost.
                let _ = self
                    .inner
                    .cv
                    .wait_timeout(guard, remaining.min(Duration::from_millis(10)))
                    .unwrap();
            } else {
                drop(guard);
            }
            self.inner.waiters.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

impl Queue for ShardedQueue {
    fn send(&self, body: &str, priority: i64) {
        self.send_hinted(body, priority, None);
    }

    fn send_hinted(&self, body: &str, priority: i64, hint: Option<u64>) {
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        self.shard_for_id(id)
            .lock()
            .unwrap()
            .insert_hinted(id, body, priority, hint, self.clock.now());
        self.inner.epoch.fetch_add(1, Ordering::SeqCst);
        // Fast path: nobody parked → no global lock on the send path.
        if self.inner.waiters.load(Ordering::SeqCst) > 0 {
            // Lock the park mutex so the notify cannot slip between a
            // parked receiver's epoch re-check and its wait.
            let _guard = self.inner.park.lock().unwrap();
            // One new message → one receiver is enough to wake.
            self.inner.cv.notify_one();
        }
    }

    fn receive(&self) -> Option<(String, Lease)> {
        self.scan(None)
    }

    fn receive_for(&self, worker: u64) -> Option<(String, Lease)> {
        self.scan(Some(worker))
    }

    fn receive_timeout(&self, timeout: Duration) -> Option<(String, Lease)> {
        self.scan_timeout(None, timeout)
    }

    fn receive_timeout_for(&self, worker: u64, timeout: Duration) -> Option<(String, Lease)> {
        self.scan_timeout(Some(worker), timeout)
    }

    fn renew(&self, lease: &Lease) -> bool {
        let now = self.clock.now();
        self.shard_for_id(lease.msg_id)
            .lock()
            .unwrap()
            .renew(lease, now, self.default_lease)
    }

    fn delete(&self, lease: &Lease) -> bool {
        self.shard_for_id(lease.msg_id).lock().unwrap().delete(lease)
    }

    fn len(&self) -> usize {
        self.inner
            .shards
            .iter()
            .map(|s| s.lock().unwrap().len())
            .sum()
    }

    fn visible_len(&self) -> usize {
        let now = self.clock.now();
        self.inner
            .shards
            .iter()
            .map(|s| s.lock().unwrap().visible_len(now))
            .sum()
    }

    fn delivery_count(&self, body: &str) -> u32 {
        self.inner
            .shards
            .iter()
            .find_map(|s| s.lock().unwrap().delivery_count(body))
            .unwrap_or(0)
    }

    fn purge_prefix(&self, body_prefix: &str) -> usize {
        // Per-shard sweep, one lock at a time (messages of one body
        // prefix are spread round-robin across every shard).
        self.inner
            .shards
            .iter()
            .map(|s| s.lock().unwrap().purge_prefix(body_prefix))
            .sum()
    }

    fn set_claim_weights(&self, weights: Arc<ClaimWeights>) {
        *self.inner.weights.write().unwrap() = Some(weights);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::clock::TestClock;

    #[test]
    fn send_receive_delete_across_shard_counts() {
        for n in [1usize, 3, 8] {
            let q = ShardedQueue::new(n, Duration::from_secs(10));
            q.send("t1", 0);
            let (body, lease) = q.receive().unwrap();
            assert_eq!(body, "t1");
            assert!(q.receive().is_none(), "invisible while leased");
            assert!(q.delete(&lease));
            assert!(q.is_empty());
        }
    }

    #[test]
    fn single_shard_is_globally_ordered() {
        let q = ShardedQueue::new(1, Duration::from_secs(10));
        q.send("low-1", 1);
        q.send("high", 5);
        q.send("low-2", 1);
        assert_eq!(q.receive().unwrap().0, "high");
        assert_eq!(q.receive().unwrap().0, "low-1", "FIFO within priority");
        assert_eq!(q.receive().unwrap().0, "low-2");
    }

    #[test]
    fn lease_expiry_redelivers_with_stale_rejection() {
        let clock = Arc::new(TestClock::default());
        let q = ShardedQueue::with_clock(4, Duration::from_secs(10), clock.clone());
        q.send("t", 0);
        let (_, lease1) = q.receive().unwrap();
        assert!(q.receive().is_none());
        clock.advance(Duration::from_secs(11));
        let (_, lease2) = q.receive().unwrap();
        assert_eq!(q.delivery_count("t"), 2);
        assert!(!q.renew(&lease1));
        assert!(!q.delete(&lease1));
        assert!(q.delete(&lease2));
        assert!(q.is_empty());
    }

    #[test]
    fn no_message_lost_or_duplicated_under_concurrent_receivers() {
        let q = ShardedQueue::new(8, Duration::from_secs(30));
        for i in 0..128 {
            q.send(&format!("m{i}"), (i % 3) as i64);
        }
        assert_eq!(q.len(), 128);
        assert_eq!(q.visible_len(), 128);
        let mut handles = Vec::new();
        for _ in 0..8 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some((body, lease)) = q.receive() {
                    got.push(body);
                    assert!(q.delete(&lease));
                }
                got
            }));
        }
        let mut all: Vec<String> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 128, "each message delivered exactly once here");
        assert!(q.is_empty());
    }

    #[test]
    fn purge_prefix_sweeps_every_shard() {
        for n in [1usize, 3, 8] {
            let q = ShardedQueue::new(n, Duration::from_secs(10));
            for i in 0..12 {
                q.send(&format!("1|t{i}"), 0);
                q.send(&format!("2|t{i}"), 0);
            }
            let (_, lease) = q.receive().unwrap();
            assert_eq!(q.purge_prefix("1|"), 12, "[{n} shards]");
            assert_eq!(q.len(), 12, "[{n} shards]");
            // Whichever message was leased, its lease is now either
            // stale (job-1 purged) or still valid (job 2).
            let _ = q.delete(&lease);
            assert_eq!(q.purge_prefix("2|"), q.len(), "[{n} shards]");
            assert!(q.is_empty(), "[{n} shards]");
        }
    }

    #[test]
    fn hinted_messages_steer_toward_their_worker_among_equal_priority() {
        // Frozen clock: hints stay fresh regardless of test-host pace.
        let clock = Arc::new(TestClock::default());
        let q = ShardedQueue::with_clock(1, Duration::from_secs(10), clock);
        q.send_hinted("for-7", 0, Some(7));
        q.send("anyone", 0);
        // Worker 9 skips the fresh hint and takes the unhinted task,
        // even though FIFO order would give it "for-7".
        assert_eq!(q.receive_for(9).unwrap().0, "anyone");
        // The hinted worker claims its own task.
        assert_eq!(q.receive_for(7).unwrap().0, "for-7");
    }

    #[test]
    fn steering_never_inverts_priority() {
        let clock = Arc::new(TestClock::default());
        let q = ShardedQueue::with_clock(1, Duration::from_secs(10), clock);
        q.send_hinted("high-for-7", 5, Some(7));
        q.send("low", 1);
        // Worker 9 must take the higher-priority task (fallback to the
        // steered message), never the lower-priority unhinted one.
        assert_eq!(q.receive_for(9).unwrap().0, "high-for-7");
        assert_eq!(q.receive_for(9).unwrap().0, "low");
    }

    #[test]
    fn all_hinted_elsewhere_falls_back_fifo_without_starving() {
        let clock = Arc::new(TestClock::default());
        let q = ShardedQueue::with_clock(1, Duration::from_secs(10), clock);
        q.send_hinted("first", 0, Some(7));
        q.send_hinted("second", 0, Some(7));
        // No unhinted candidate exists: worker 9 still gets work, in
        // FIFO order — a hint is a preference, never a reservation.
        assert_eq!(q.receive_for(9).unwrap().0, "first");
        assert_eq!(q.receive_for(9).unwrap().0, "second");
        assert!(q.receive_for(9).is_none());
    }

    #[test]
    fn hints_age_out_after_the_staleness_bound() {
        let clock = Arc::new(TestClock::default());
        let q = ShardedQueue::with_clock(1, Duration::from_secs(10), clock.clone())
            .with_hint_staleness(Duration::from_secs(1));
        q.send_hinted("for-7", 0, Some(7));
        q.send("anyone", 0);
        assert_eq!(q.receive_for(9).unwrap().0, "anyone", "fresh hint steers");
        clock.advance(Duration::from_secs(2));
        // Hint is past the staleness bound — worker 9 claims it.
        assert_eq!(q.receive_for(9).unwrap().0, "for-7");
    }

    #[test]
    fn plain_receive_ignores_hints() {
        let q = ShardedQueue::new(1, Duration::from_secs(10));
        q.send_hinted("for-7", 0, Some(7));
        q.send("anyone", 0);
        assert_eq!(q.receive().unwrap().0, "for-7", "FIFO, hint-agnostic");
    }

    #[test]
    fn steered_receive_honors_leases_and_redelivery() {
        let clock = Arc::new(TestClock::default());
        let q = ShardedQueue::with_clock(1, Duration::from_secs(10), clock.clone());
        q.send_hinted("t", 0, Some(7));
        let (_, lease) = q.receive_for(7).unwrap();
        assert!(q.receive_for(7).is_none(), "invisible while leased");
        clock.advance(Duration::from_secs(11));
        let (_, lease2) = q.receive_for(9).unwrap();
        assert!(!q.delete(&lease), "stale lease rejected");
        assert!(q.delete(&lease2));
        assert!(q.is_empty());
    }

    #[test]
    fn claim_weights_prefer_the_starved_job_within_a_priority() {
        let q = ShardedQueue::new(1, Duration::from_secs(10));
        let w = Arc::new(ClaimWeights::default());
        w.set(1, 0.5);
        w.set(2, 8.0);
        q.set_claim_weights(w);
        // Job 1 enqueued first; equal priority; job 2 is starved
        // (higher pending-to-inflight weight) so it claims first.
        q.send("1|a", 0);
        q.send("2|b", 0);
        q.send("1|c", 0);
        assert_eq!(q.receive_for(3).unwrap().0, "2|b");
        // FIFO among the remaining (same-weight) messages.
        assert_eq!(q.receive_for(3).unwrap().0, "1|a");
        assert_eq!(q.receive_for(3).unwrap().0, "1|c");
    }

    #[test]
    fn claim_weights_never_invert_priority_and_equal_weights_keep_fifo() {
        let q = ShardedQueue::new(1, Duration::from_secs(10));
        let w = Arc::new(ClaimWeights::default());
        w.set(1, 1.0);
        w.set(2, 100.0);
        q.set_claim_weights(w);
        // Job 2's weight cannot pull its low-priority task ahead of
        // job 1's high-priority one.
        q.send("2|low", 1);
        q.send("1|high", 5);
        assert_eq!(q.receive_for(3).unwrap().0, "1|high");
        assert_eq!(q.receive_for(3).unwrap().0, "2|low");
        // Equal weights: exact FIFO, byte-identical to unweighted.
        let q = ShardedQueue::new(1, Duration::from_secs(10));
        let w = Arc::new(ClaimWeights::default());
        w.set(1, 2.0);
        w.set(2, 2.0);
        q.set_claim_weights(w);
        q.send("1|first", 0);
        q.send("2|second", 0);
        assert_eq!(q.receive_for(3).unwrap().0, "1|first");
        assert_eq!(q.receive_for(3).unwrap().0, "2|second");
    }

    #[test]
    fn single_job_weight_map_is_inert_and_plain_receive_ignores_weights() {
        let q = ShardedQueue::new(1, Duration::from_secs(10));
        let w = Arc::new(ClaimWeights::default());
        w.set(2, 100.0);
        q.set_claim_weights(w.clone());
        q.send("1|a", 0);
        q.send("2|b", 0);
        // One job in the map → fair share inactive → FIFO.
        assert_eq!(q.receive_for(3).unwrap().0, "1|a");
        // Two jobs → active, but plain receive stays weight-agnostic.
        w.set(1, 1.0);
        q.send("1|c", 0);
        assert_eq!(q.receive().unwrap().0, "2|b", "FIFO for plain receive");
        assert_eq!(q.receive_for(3).unwrap().0, "1|c");
    }

    #[test]
    fn claim_weights_compose_with_hint_steering() {
        let clock = Arc::new(TestClock::default());
        let q = ShardedQueue::with_clock(1, Duration::from_secs(10), clock);
        let w = Arc::new(ClaimWeights::default());
        w.set(1, 1.0);
        w.set(2, 8.0);
        q.set_claim_weights(w);
        // The heavy job's only task is freshly hinted at worker 7, so
        // worker 9 defers it and weight picks among the unsteered rest.
        q.send_hinted("2|hinted", 0, Some(7));
        q.send("1|a", 0);
        assert_eq!(q.receive_for(9).unwrap().0, "1|a");
        // Nothing unsteered left → FIFO-best steered message anyway.
        assert_eq!(q.receive_for(9).unwrap().0, "2|hinted");
    }

    #[test]
    fn blocking_receive_wakes_on_send() {
        let q = ShardedQueue::new(4, Duration::from_secs(10));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.receive_timeout(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(30));
        q.send("x", 0);
        assert_eq!(h.join().unwrap().unwrap().0, "x");
        assert!(q.receive_timeout(Duration::from_millis(30)).is_none());
    }
}
