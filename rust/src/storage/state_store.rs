//! Redis-like runtime state store.
//!
//! §4 step 4: "the runtime state store tracks the control state of the
//! entire execution and needs to support fast, atomic updates for each
//! task". The operations numpywren's protocol needs — and all we
//! provide — are per-key linearizable RMW:
//!
//! * `cas` — task-status transitions (exactly one worker wins the
//!   `Pending → Completed` transition and performs child enqueue);
//! * `set_nx` — per-edge "decremented" markers making dependency
//!   decrements idempotent under task re-execution;
//! * `decr`/`init_counter` — lazily-initialized dependency counters
//!   (DESIGN.md §5.2);
//! * plain get/set for job metadata and counters for metrics.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Task status values used by the engine (stored as strings — the
/// store itself is schema-less, like Redis).
pub mod status {
    pub const PENDING: &str = "pending";
    pub const RUNNING: &str = "running";
    pub const COMPLETED: &str = "completed";
}

/// The store. Clone-shared.
#[derive(Clone, Default)]
pub struct StateStore {
    kv: Arc<Mutex<HashMap<String, String>>>,
    counters: Arc<Mutex<HashMap<String, i64>>>,
    ops: Arc<AtomicU64>,
}

impl StateStore {
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(&self) {
        self.ops.fetch_add(1, Ordering::Relaxed);
    }

    /// Total operations served (control-plane load metric).
    pub fn op_count(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    pub fn get(&self, key: &str) -> Option<String> {
        self.bump();
        self.kv.lock().unwrap().get(key).cloned()
    }

    pub fn set(&self, key: &str, value: &str) {
        self.bump();
        self.kv
            .lock()
            .unwrap()
            .insert(key.to_string(), value.to_string());
    }

    /// Set iff absent. Returns true when this call created the key —
    /// the idempotence primitive (only the first caller proceeds).
    pub fn set_nx(&self, key: &str, value: &str) -> bool {
        self.bump();
        let mut kv = self.kv.lock().unwrap();
        if kv.contains_key(key) {
            false
        } else {
            kv.insert(key.to_string(), value.to_string());
            true
        }
    }

    /// Compare-and-swap: if current == `expect` (None = absent), set to
    /// `value` and return true.
    pub fn cas(&self, key: &str, expect: Option<&str>, value: &str) -> bool {
        self.bump();
        let mut kv = self.kv.lock().unwrap();
        let cur = kv.get(key).map(|s| s.as_str());
        if cur == expect {
            kv.insert(key.to_string(), value.to_string());
            true
        } else {
            false
        }
    }

    /// Initialize a counter iff absent; returns true if this call
    /// initialized it.
    pub fn init_counter(&self, key: &str, value: i64) -> bool {
        self.bump();
        let mut c = self.counters.lock().unwrap();
        if c.contains_key(key) {
            false
        } else {
            c.insert(key.to_string(), value);
            true
        }
    }

    /// Atomically add `delta` (counter created as 0 if absent);
    /// returns the new value.
    pub fn incr(&self, key: &str, delta: i64) -> i64 {
        self.bump();
        let mut c = self.counters.lock().unwrap();
        let v = c.entry(key.to_string()).or_insert(0);
        *v += delta;
        *v
    }

    /// Atomically decrement; returns the new value.
    pub fn decr(&self, key: &str) -> i64 {
        self.incr(key, -1)
    }

    pub fn counter(&self, key: &str) -> i64 {
        self.bump();
        *self.counters.lock().unwrap().get(key).unwrap_or(&0)
    }

    /// Does the counter exist (distinct from == 0)?
    pub fn counter_exists(&self, key: &str) -> bool {
        self.counters.lock().unwrap().contains_key(key)
    }

    /// The dependency-propagation primitive: atomically, if `edge_key`
    /// has not been marked, mark it and decrement `counter_key`.
    /// Returns the counter value after the (possibly skipped)
    /// decrement. Idempotent per edge — a re-executed parent task
    /// re-observes the value instead of double-decrementing, and a
    /// worker that crashed between the decrement and the child enqueue
    /// lets its successor re-observe the 0 and enqueue (at-least-once
    /// enqueue is safe; execution is idempotent).
    pub fn edge_decr(&self, edge_key: &str, counter_key: &str) -> i64 {
        self.bump();
        let mut c = self.counters.lock().unwrap();
        if c.contains_key(edge_key) {
            *c.get(counter_key).unwrap_or(&0)
        } else {
            c.insert(edge_key.to_string(), 1);
            let v = c.entry(counter_key.to_string()).or_insert(0);
            *v -= 1;
            *v
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn get_set() {
        let s = StateStore::new();
        assert_eq!(s.get("k"), None);
        s.set("k", "v");
        assert_eq!(s.get("k").as_deref(), Some("v"));
    }

    #[test]
    fn cas_transitions() {
        let s = StateStore::new();
        assert!(s.cas("t", None, status::PENDING));
        assert!(!s.cas("t", None, status::PENDING), "already exists");
        assert!(s.cas("t", Some(status::PENDING), status::COMPLETED));
        assert!(
            !s.cas("t", Some(status::PENDING), status::COMPLETED),
            "second completer must lose"
        );
    }

    #[test]
    fn set_nx_exactly_one_winner_concurrent() {
        let s = StateStore::new();
        let mut handles = Vec::new();
        for i in 0..16 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || s.set_nx("edge:a:b", &i.to_string())));
        }
        let wins: usize = handles
            .into_iter()
            .map(|h| h.join().unwrap() as usize)
            .sum();
        assert_eq!(wins, 1);
    }

    #[test]
    fn concurrent_decrements_hit_zero_exactly_once() {
        // The dependency-counter invariant: N workers each decrement
        // once; exactly one observes the 0 crossing.
        let s = StateStore::new();
        s.init_counter("deps", 16);
        let mut handles = Vec::new();
        for _ in 0..16 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || s.decr("deps") == 0));
        }
        let zeros: usize = handles
            .into_iter()
            .map(|h| h.join().unwrap() as usize)
            .sum();
        assert_eq!(zeros, 1);
        assert_eq!(s.counter("deps"), 0);
    }

    #[test]
    fn init_counter_only_first_wins() {
        let s = StateStore::new();
        assert!(s.init_counter("c", 5));
        assert!(!s.init_counter("c", 99));
        assert_eq!(s.counter("c"), 5);
    }

    #[test]
    fn edge_decr_idempotent() {
        let s = StateStore::new();
        s.init_counter("deps:c", 3);
        assert_eq!(s.edge_decr("edge:a:c", "deps:c"), 2);
        // Re-execution of parent a: no double decrement, value observed.
        assert_eq!(s.edge_decr("edge:a:c", "deps:c"), 2);
        assert_eq!(s.edge_decr("edge:b:c", "deps:c"), 1);
        assert_eq!(s.edge_decr("edge:d:c", "deps:c"), 0);
        assert_eq!(s.edge_decr("edge:d:c", "deps:c"), 0);
    }

    #[test]
    fn edge_decr_concurrent_zero_crossing() {
        // n distinct parents racing (with duplicates): counter ends at
        // exactly 0 and at least one caller observes 0.
        let s = StateStore::new();
        let n = 8;
        s.init_counter("deps", n);
        let mut handles = Vec::new();
        for i in 0..n {
            for _dup in 0..3 {
                let s = s.clone();
                handles.push(std::thread::spawn(move || {
                    s.edge_decr(&format!("edge:{i}"), "deps") == 0
                }));
            }
        }
        let zeros: usize = handles
            .into_iter()
            .map(|h| h.join().unwrap() as usize)
            .sum();
        assert!(zeros >= 1);
        assert_eq!(s.counter("deps"), 0);
    }

    #[test]
    fn prop_counter_sum_invariant() {
        // Random interleavings of incr/decr across threads conserve the
        // arithmetic sum.
        forall("counter conserves sum", 99, 16, |rng, _| {
            let s = StateStore::new();
            let n_threads = 1 + rng.below(6);
            let per = 1 + rng.below(50);
            let deltas: Vec<Vec<i64>> = (0..n_threads)
                .map(|_| (0..per).map(|_| rng.range_i64(-3, 3)).collect())
                .collect();
            let expected: i64 = deltas.iter().flatten().sum();
            let mut handles = Vec::new();
            for d in deltas {
                let s = s.clone();
                handles.push(std::thread::spawn(move || {
                    for x in d {
                        s.incr("c", x);
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            prop_assert_eq!(s.counter("c"), expected);
            prop_assert!(s.op_count() > 0);
            Ok(())
        });
    }
}
