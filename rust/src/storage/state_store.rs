//! Single-lock Redis-like runtime state store — the `strict` KV
//! backend.
//!
//! §4 step 4: "the runtime state store tracks the control state of the
//! entire execution and needs to support fast, atomic updates for each
//! task". The operations numpywren's protocol needs — and all the
//! [`KvState`] trait asks for — are per-key linearizable RMW:
//!
//! * `cas` — task-status transitions (exactly one worker wins the
//!   `Pending → Completed` transition and performs child enqueue);
//! * `set_nx` — per-edge "decremented" markers making dependency
//!   decrements idempotent under task re-execution;
//! * `decr`/`init_counter` — lazily-initialized dependency counters
//!   (DESIGN.md §5.2);
//! * plain get/set for job metadata and counters for metrics.

use crate::storage::traits::KvState;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Task status values used by the engine (stored as strings — the
/// store itself is schema-less, like Redis).
pub mod status {
    pub const PENDING: &str = "pending";
    pub const RUNNING: &str = "running";
    pub const COMPLETED: &str = "completed";
}

/// The store. Clone-shared.
#[derive(Clone, Default)]
pub struct StrictKvState {
    kv: Arc<Mutex<HashMap<String, String>>>,
    counters: Arc<Mutex<HashMap<String, i64>>>,
    ops: Arc<AtomicU64>,
}

impl StrictKvState {
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(&self) {
        self.ops.fetch_add(1, Ordering::Relaxed);
    }
}

impl KvState for StrictKvState {
    fn op_count(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    fn get(&self, key: &str) -> Option<String> {
        self.bump();
        self.kv.lock().unwrap().get(key).cloned()
    }

    fn set(&self, key: &str, value: &str) {
        self.bump();
        self.kv
            .lock()
            .unwrap()
            .insert(key.to_string(), value.to_string());
    }

    fn set_nx(&self, key: &str, value: &str) -> bool {
        self.bump();
        let mut kv = self.kv.lock().unwrap();
        if kv.contains_key(key) {
            false
        } else {
            kv.insert(key.to_string(), value.to_string());
            true
        }
    }

    fn cas(&self, key: &str, expect: Option<&str>, value: &str) -> bool {
        self.bump();
        let mut kv = self.kv.lock().unwrap();
        let cur = kv.get(key).map(|s| s.as_str());
        if cur == expect {
            kv.insert(key.to_string(), value.to_string());
            true
        } else {
            false
        }
    }

    fn init_counter(&self, key: &str, value: i64) -> bool {
        self.bump();
        let mut c = self.counters.lock().unwrap();
        if c.contains_key(key) {
            false
        } else {
            c.insert(key.to_string(), value);
            true
        }
    }

    fn incr(&self, key: &str, delta: i64) -> i64 {
        self.bump();
        let mut c = self.counters.lock().unwrap();
        let v = c.entry(key.to_string()).or_insert(0);
        *v += delta;
        *v
    }

    fn counter(&self, key: &str) -> i64 {
        self.bump();
        *self.counters.lock().unwrap().get(key).unwrap_or(&0)
    }

    fn counter_exists(&self, key: &str) -> bool {
        self.counters.lock().unwrap().contains_key(key)
    }

    fn delete(&self, key: &str) -> bool {
        self.bump();
        let in_kv = self.kv.lock().unwrap().remove(key).is_some();
        let in_counters = self.counters.lock().unwrap().remove(key).is_some();
        in_kv || in_counters
    }

    fn scan_prefix(&self, prefix: &str) -> Vec<String> {
        let mut keys: Vec<String> = self
            .kv
            .lock()
            .unwrap()
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        keys.extend(
            self.counters
                .lock()
                .unwrap()
                .keys()
                .filter(|k| k.starts_with(prefix))
                .cloned(),
        );
        keys.sort_unstable();
        keys.dedup();
        keys
    }

    fn delete_prefix(&self, prefix: &str) -> usize {
        self.bump();
        let mut removed = 0;
        {
            let mut kv = self.kv.lock().unwrap();
            let before = kv.len();
            kv.retain(|k, _| !k.starts_with(prefix));
            removed += before - kv.len();
        }
        {
            let mut c = self.counters.lock().unwrap();
            let before = c.len();
            c.retain(|k, _| !k.starts_with(prefix));
            removed += before - c.len();
        }
        removed
    }

    fn edge_decr(&self, edge_key: &str, counter_key: &str) -> i64 {
        self.bump();
        let mut c = self.counters.lock().unwrap();
        if c.contains_key(edge_key) {
            *c.get(counter_key).unwrap_or(&0)
        } else {
            c.insert(edge_key.to_string(), 1);
            let v = c.entry(counter_key.to_string()).or_insert(0);
            *v -= 1;
            *v
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::{prop_assert, prop_assert_eq};

    #[test]
    fn get_set() {
        let s = StrictKvState::new();
        assert_eq!(s.get("k"), None);
        s.set("k", "v");
        assert_eq!(s.get("k").as_deref(), Some("v"));
    }

    #[test]
    fn cas_transitions() {
        let s = StrictKvState::new();
        assert!(s.cas("t", None, status::PENDING));
        assert!(!s.cas("t", None, status::PENDING), "already exists");
        assert!(s.cas("t", Some(status::PENDING), status::COMPLETED));
        assert!(
            !s.cas("t", Some(status::PENDING), status::COMPLETED),
            "second completer must lose"
        );
    }

    #[test]
    fn set_nx_exactly_one_winner_concurrent() {
        let s = StrictKvState::new();
        let mut handles = Vec::new();
        for i in 0..16 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || s.set_nx("edge:a:b", &i.to_string())));
        }
        let wins: usize = handles
            .into_iter()
            .map(|h| h.join().unwrap() as usize)
            .sum();
        assert_eq!(wins, 1);
    }

    #[test]
    fn concurrent_decrements_hit_zero_exactly_once() {
        // The dependency-counter invariant: N workers each decrement
        // once; exactly one observes the 0 crossing.
        let s = StrictKvState::new();
        s.init_counter("deps", 16);
        let mut handles = Vec::new();
        for _ in 0..16 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || s.decr("deps") == 0));
        }
        let zeros: usize = handles
            .into_iter()
            .map(|h| h.join().unwrap() as usize)
            .sum();
        assert_eq!(zeros, 1);
        assert_eq!(s.counter("deps"), 0);
    }

    #[test]
    fn init_counter_only_first_wins() {
        let s = StrictKvState::new();
        assert!(s.init_counter("c", 5));
        assert!(!s.init_counter("c", 99));
        assert_eq!(s.counter("c"), 5);
    }

    #[test]
    fn edge_decr_idempotent() {
        let s = StrictKvState::new();
        s.init_counter("deps:c", 3);
        assert_eq!(s.edge_decr("edge:a:c", "deps:c"), 2);
        // Re-execution of parent a: no double decrement, value observed.
        assert_eq!(s.edge_decr("edge:a:c", "deps:c"), 2);
        assert_eq!(s.edge_decr("edge:b:c", "deps:c"), 1);
        assert_eq!(s.edge_decr("edge:d:c", "deps:c"), 0);
        assert_eq!(s.edge_decr("edge:d:c", "deps:c"), 0);
    }

    #[test]
    fn edge_decr_concurrent_zero_crossing() {
        // n distinct parents racing (with duplicates): counter ends at
        // exactly 0 and at least one caller observes 0.
        let s = StrictKvState::new();
        let n = 8;
        s.init_counter("deps", n);
        let mut handles = Vec::new();
        for i in 0..n {
            for _dup in 0..3 {
                let s = s.clone();
                handles.push(std::thread::spawn(move || {
                    s.edge_decr(&format!("edge:{i}"), "deps") == 0
                }));
            }
        }
        let zeros: usize = handles
            .into_iter()
            .map(|h| h.join().unwrap() as usize)
            .sum();
        assert!(zeros >= 1);
        assert_eq!(s.counter("deps"), 0);
    }

    #[test]
    fn delete_and_prefix_sweep_cover_both_spaces() {
        let s = StrictKvState::new();
        s.set("j1/status:a", status::COMPLETED);
        s.init_counter("j1/deps:b", 2);
        s.edge_decr("j1/edge:a:b", "j1/deps:b");
        s.set("j2/status:a", status::PENDING);
        assert_eq!(
            s.scan_prefix("j1/"),
            vec![
                "j1/deps:b".to_string(),
                "j1/edge:a:b".to_string(),
                "j1/status:a".to_string()
            ]
        );
        // delete spans the string KV and the counter space.
        assert!(s.delete("j1/deps:b"));
        assert!(!s.delete("j1/deps:b"));
        assert!(!s.counter_exists("j1/deps:b"));
        assert_eq!(s.delete_prefix("j1/"), 2, "status + edge guard");
        assert_eq!(s.delete_prefix("j1/"), 0);
        assert_eq!(s.get("j2/status:a").as_deref(), Some(status::PENDING));
    }

    #[test]
    fn prop_counter_sum_invariant() {
        // Random interleavings of incr/decr across threads conserve the
        // arithmetic sum.
        forall("counter conserves sum", 99, 16, |rng, _| {
            let s = StrictKvState::new();
            let n_threads = 1 + rng.below(6);
            let per = 1 + rng.below(50);
            let deltas: Vec<Vec<i64>> = (0..n_threads)
                .map(|_| (0..per).map(|_| rng.range_i64(-3, 3)).collect())
                .collect();
            let expected: i64 = deltas.iter().flatten().sum();
            let mut handles = Vec::new();
            for d in deltas {
                let s = s.clone();
                handles.push(std::thread::spawn(move || {
                    for x in d {
                        s.incr("c", x);
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            prop_assert_eq!(s.counter("c"), expected);
            prop_assert!(s.op_count() > 0);
            Ok(())
        });
    }
}
