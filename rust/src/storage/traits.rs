//! The substrate abstraction — one object-safe trait per cloud
//! service the paper builds on (§4, Figure 6).
//!
//! Everything above the substrate (engine, executor, provisioner)
//! holds `Arc<dyn …>` handles to these traits, never concrete types,
//! so backends are interchangeable: the single-lock `strict` family
//! (linearizable, test-friendly, SSA-checking), the `sharded` family
//! (N-way key-hash sharding for high worker counts), the composable
//! fault/latency decorators in [`crate::storage::chaos`], and —
//! eventually — real S3/SQS/Redis clients.
//!
//! Semantics every backend must provide (the conformance suite in
//! `tests/substrate_conformance.rs` checks both shipped families):
//!
//! * [`BlobStore`] — S3: unbounded keyed tile storage,
//!   read-after-write consistency *per key*, byte/op accounting per
//!   logical worker;
//! * [`Queue`] — SQS: at-least-once delivery with visibility-timeout
//!   leases; renewal and delete require the current lease; **FIFO
//!   within a priority** by global enqueue order (sequence-number
//!   tiebreak), so same-priority tasks pop deterministically —
//!   sharded backends may relax cross-shard ordering but never lose
//!   or duplicate a live lease;
//! * [`KvState`] — Redis: per-key linearizable RMW (`cas`, `set_nx`,
//!   counters) plus the two-key [`KvState::edge_decr`] dependency
//!   primitive, atomic across both keys.
//!
//! **Lifecycle ops** (the substrate-GC surface): every backend also
//! provides `delete` / `scan_prefix` / `delete_prefix` on the blob and
//! KV stores and [`Queue::purge_prefix`] on the queue, so the runtime
//! can reclaim a finished job's `jN/` namespace — dead intermediate
//! tiles, status/deps/edge entries, and queue residue — instead of
//! leaking it for the life of the service (§4's intermediate-state
//! burden). The prefix ops return counts so callers can assert exact
//! reclamation. `scan_prefix` returns sorted keys (deterministic
//! across backends); prefix sweeps need no cross-key atomicity — the
//! caller guarantees the namespace is quiescent before sweeping.

use crate::linalg::matrix::Matrix;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

/// Aggregate transfer statistics.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub get_ops: u64,
    pub put_ops: u64,
}

/// A held lease on a queue message. Deleting or renewing requires the
/// lease; a stale lease (superseded by redelivery) is rejected.
/// Message ids are globally unique within a queue, so sharded backends
/// can route a lease back to its shard.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Lease {
    pub(crate) msg_id: u64,
    pub(crate) receipt: u64,
}

/// S3-like tile store: high-throughput keyed storage with per-key
/// read-after-write consistency and transfer accounting.
pub trait BlobStore: Send + Sync {
    /// Store a tile under `key`, attributed to `worker`.
    fn put(&self, worker: usize, key: &str, value: Matrix) -> Result<()>;

    /// Fetch the tile at `key`, attributed to `worker`.
    fn get(&self, worker: usize, key: &str) -> Result<Arc<Matrix>>;

    /// Does `key` exist? (No latency or accounting — control-plane op.)
    fn contains(&self, key: &str) -> bool;

    /// Delete the tile at `key`; returns whether it existed. Fallible
    /// like `put`/`get` — the chaos layer injects transient faults
    /// here too, so GC callers retry exactly as workers do.
    fn delete(&self, key: &str) -> Result<bool>;

    /// Keys starting with `prefix`, sorted. Control-plane op (no
    /// accounting) — the runtime's namespace-listing primitive, like
    /// S3 `ListObjectsV2` with a prefix.
    fn scan_prefix(&self, prefix: &str) -> Vec<String>;

    /// Bulk-delete every key under `prefix`; returns the number of
    /// objects removed (callers assert reclamation against it). The
    /// analogue of an S3 lifecycle sweep: infallible and idempotent.
    fn delete_prefix(&self, prefix: &str) -> usize;

    /// Number of stored objects.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Aggregate stats.
    fn stats(&self) -> StoreStats;

    /// Per-worker stats (Figure 7's per-machine bytes).
    fn worker_stats(&self, worker: usize) -> StoreStats;

    /// Ids of workers that have touched the store.
    fn known_workers(&self) -> Vec<usize>;
}

/// SQS-like task queue: at-least-once delivery with visibility-timeout
/// leases (the §4.1 fault-tolerance protocol rests on these exact
/// guarantees). Highest priority first among visible messages; ties
/// break FIFO by enqueue order.
pub trait Queue: Send + Sync {
    /// Enqueue a message.
    fn send(&self, body: &str, priority: i64);

    /// Try to receive the best visible message; takes a lease for the
    /// queue's default lease duration. Non-blocking.
    fn receive(&self) -> Option<(String, Lease)>;

    /// Blocking receive with timeout. Returns `None` on timeout.
    fn receive_timeout(&self, timeout: Duration) -> Option<(String, Lease)>;

    /// Renew the lease for another lease period from now. Fails if the
    /// lease is stale (message redelivered or deleted).
    fn renew(&self, lease: &Lease) -> bool;

    /// Delete the message — only valid while holding the current lease
    /// (the §4.1 invariant: delete only after effects are durable).
    fn delete(&self, lease: &Lease) -> bool;

    /// Number of messages (visible + invisible) — the provisioner's
    /// "pending tasks" signal.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of currently-visible messages.
    fn visible_len(&self) -> usize;

    /// How many times the message body has been delivered (testing
    /// aid; at-least-once shows up as counts > 1).
    fn delivery_count(&self, body: &str) -> u32;

    /// Remove every message whose body starts with `body_prefix`,
    /// leased or not; returns the number purged. Held leases on purged
    /// messages become stale (renew/delete return false). The
    /// runtime's queue-residue drain: a finished job's messages are
    /// `jobid|…`, so one prefix purge empties its backlog without
    /// waiting for workers to receive-and-drop each one.
    fn purge_prefix(&self, body_prefix: &str) -> usize;
}

/// Redis-like runtime state store: per-key linearizable RMW — all the
/// control-plane atomicity numpywren's protocol needs (§4 step 4).
pub trait KvState: Send + Sync {
    fn get(&self, key: &str) -> Option<String>;

    fn set(&self, key: &str, value: &str);

    /// Set iff absent. Returns true when this call created the key —
    /// the idempotence primitive (only the first caller proceeds).
    fn set_nx(&self, key: &str, value: &str) -> bool;

    /// Compare-and-swap: if current == `expect` (None = absent), set
    /// to `value` and return true.
    fn cas(&self, key: &str, expect: Option<&str>, value: &str) -> bool;

    /// Initialize a counter iff absent; returns true if this call
    /// initialized it.
    fn init_counter(&self, key: &str, value: i64) -> bool;

    /// Atomically add `delta` (counter created as 0 if absent);
    /// returns the new value.
    fn incr(&self, key: &str, delta: i64) -> i64;

    /// Atomically decrement; returns the new value.
    fn decr(&self, key: &str) -> i64 {
        self.incr(key, -1)
    }

    fn counter(&self, key: &str) -> i64;

    /// Does the counter exist (distinct from == 0)?
    fn counter_exists(&self, key: &str) -> bool;

    /// Delete `key` from the string KV *and* the counter space;
    /// returns whether anything existed under it.
    fn delete(&self, key: &str) -> bool;

    /// Keys starting with `prefix` across both the string KV and the
    /// counter space (status, deps, edge guards, counters), sorted and
    /// deduplicated.
    fn scan_prefix(&self, prefix: &str) -> Vec<String>;

    /// Bulk-delete every entry (string or counter) under `prefix`;
    /// returns the number of entries removed. A key present in both
    /// spaces counts twice — job namespaces keep the two disjoint.
    fn delete_prefix(&self, prefix: &str) -> usize;

    /// The dependency-propagation primitive: atomically, if `edge_key`
    /// has not been marked, mark it and decrement `counter_key`.
    /// Returns the counter value after the (possibly skipped)
    /// decrement. Idempotent per edge — a re-executed parent task
    /// re-observes the value instead of double-decrementing, and a
    /// worker that crashed between the decrement and the child enqueue
    /// lets its successor re-observe the 0 and enqueue (at-least-once
    /// enqueue is safe; execution is idempotent). Both keys update
    /// under one atomic step even when a backend shards them apart.
    fn edge_decr(&self, edge_key: &str, counter_key: &str) -> i64;

    /// Total operations served (control-plane load metric).
    fn op_count(&self) -> u64;
}

/// Byte/op counters shared by the blob-store backends.
#[derive(Default)]
pub(crate) struct Counters {
    pub(crate) bytes_read: AtomicU64,
    pub(crate) bytes_written: AtomicU64,
    pub(crate) get_ops: AtomicU64,
    pub(crate) put_ops: AtomicU64,
}

impl Counters {
    pub(crate) fn snapshot(&self) -> StoreStats {
        StoreStats {
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            get_ops: self.get_ops.load(Ordering::Relaxed),
            put_ops: self.put_ops.load(Ordering::Relaxed),
        }
    }
}

/// Totals + per-worker transfer accounting (Figure 7), shared by the
/// blob-store backends. Counter bumps are lock-free; the per-worker
/// map takes its write lock only on a worker's first operation.
#[derive(Default)]
pub(crate) struct TransferAccounting {
    totals: Counters,
    per_worker: RwLock<HashMap<usize, Arc<Counters>>>,
}

impl TransferAccounting {
    fn worker_counters(&self, worker: usize) -> Arc<Counters> {
        if let Some(c) = self.per_worker.read().unwrap().get(&worker) {
            return c.clone();
        }
        let mut w = self.per_worker.write().unwrap();
        w.entry(worker).or_default().clone()
    }

    pub(crate) fn record_get(&self, worker: usize, bytes: u64) {
        self.totals.bytes_read.fetch_add(bytes, Ordering::Relaxed);
        self.totals.get_ops.fetch_add(1, Ordering::Relaxed);
        let wc = self.worker_counters(worker);
        wc.bytes_read.fetch_add(bytes, Ordering::Relaxed);
        wc.get_ops.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_put(&self, worker: usize, bytes: u64) {
        self.totals.bytes_written.fetch_add(bytes, Ordering::Relaxed);
        self.totals.put_ops.fetch_add(1, Ordering::Relaxed);
        let wc = self.worker_counters(worker);
        wc.bytes_written.fetch_add(bytes, Ordering::Relaxed);
        wc.put_ops.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn stats(&self) -> StoreStats {
        self.totals.snapshot()
    }

    pub(crate) fn worker_stats(&self, worker: usize) -> StoreStats {
        match self.per_worker.read().unwrap().get(&worker) {
            Some(c) => c.snapshot(),
            None => StoreStats::default(),
        }
    }

    pub(crate) fn known_workers(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.per_worker.read().unwrap().keys().copied().collect();
        v.sort_unstable();
        v
    }
}
