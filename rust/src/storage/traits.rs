//! The substrate abstraction — one object-safe trait per cloud
//! service the paper builds on (§4, Figure 6).
//!
//! Everything above the substrate (engine, executor, provisioner)
//! holds `Arc<dyn …>` handles to these traits, never concrete types,
//! so backends are interchangeable: the single-lock `strict` family
//! (linearizable, test-friendly, SSA-checking), the `sharded` family
//! (N-way key-hash sharding for high worker counts), the composable
//! fault/latency decorators in [`crate::storage::chaos`], and —
//! eventually — real S3/SQS/Redis clients.
//!
//! Semantics every backend must provide (the conformance suite in
//! `tests/substrate_conformance.rs` checks both shipped families):
//!
//! * [`BlobStore`] — S3: unbounded keyed tile storage,
//!   read-after-write consistency *per key*, byte/op accounting per
//!   logical worker;
//! * [`Queue`] — SQS: at-least-once delivery with visibility-timeout
//!   leases; renewal and delete require the current lease; **FIFO
//!   within a priority** by global enqueue order (sequence-number
//!   tiebreak), so same-priority tasks pop deterministically —
//!   sharded backends may relax cross-shard ordering but never lose
//!   or duplicate a live lease;
//! * [`KvState`] — Redis: per-key linearizable RMW (`cas`, `set_nx`,
//!   counters) plus the two-key [`KvState::edge_decr`] dependency
//!   primitive, atomic across both keys.
//!
//! **Lifecycle ops** (the substrate-GC surface): every backend also
//! provides `delete` / `scan_prefix` / `delete_prefix` on the blob and
//! KV stores and [`Queue::purge_prefix`] on the queue, so the runtime
//! can reclaim a finished job's `jN/` namespace — dead intermediate
//! tiles, status/deps/edge entries, and queue residue — instead of
//! leaking it for the life of the service (§4's intermediate-state
//! burden).
//!
//! The lifecycle contracts, precisely (the conformance suite pins each
//! one):
//!
//! * **Prefix-op counts.** `delete_prefix` returns the number of
//!   entries it actually removed — objects for [`BlobStore`], entries
//!   for [`KvState`] (a key present in both the string-KV and counter
//!   spaces counts *twice*; job namespaces keep the two disjoint so in
//!   practice counts equal keys), messages for
//!   [`Queue::purge_prefix`]. Callers assert exact reclamation
//!   against these counts (the leak checks in `tests/multi_job.rs` and
//!   the `perf_gc` bench), so a backend must not over- or
//!   under-report. Repeating a sweep returns 0 — the ops are
//!   idempotent and infallible (the chaos layer shapes their latency
//!   but never faults them; an S3 lifecycle rule has no error path
//!   either).
//! * **Lease-goes-stale purge semantics.** [`Queue::purge_prefix`]
//!   removes matching messages *whether or not they are currently
//!   leased*. A lease held on a purged message goes stale: subsequent
//!   [`Queue::renew`]/[`Queue::delete`] on it return `false`, exactly
//!   as if the message had been redelivered to someone else. Workers
//!   already tolerate stale leases (the §4.1 at-least-once protocol),
//!   so the GC can drain a sealed job's backlog in one call without
//!   coordinating with the fleet.
//! * **Scan determinism.** `scan_prefix` returns sorted keys on every
//!   backend, so sweeps and leak checks are deterministic regardless
//!   of shard layout. Prefix sweeps need no cross-key atomicity — the
//!   caller guarantees the namespace is quiescent (the job manager's
//!   in-flight barrier) before sweeping.
//! * **Namespace age.** [`BlobStore::prefix_age`] reports the time
//!   since the newest `put` under a prefix (reads never refresh it) —
//!   S3's per-object `LastModified` reduced to a max-over-prefix.
//!   This is the TTL sweeper's idle signal: a terminal job stops
//!   writing, so write-idle age ≈ time since it finished.

use crate::linalg::matrix::Matrix;
use anyhow::Result;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

/// Aggregate transfer statistics.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub get_ops: u64,
    pub put_ops: u64,
}

/// A held lease on a queue message. Deleting or renewing requires the
/// lease; a stale lease (superseded by redelivery) is rejected.
/// Message ids are globally unique within a queue, so sharded backends
/// can route a lease back to its shard.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Lease {
    pub(crate) msg_id: u64,
    pub(crate) receipt: u64,
}

/// Shared per-job claim weights for **dynamic fair share within a
/// scheduling class**: the job manager's monitor keeps each job's
/// weight at its pending-to-inflight ratio, and weight-aware queue
/// backends (`sharded`, `file`) prefer the highest-weight job *among
/// candidates of equal composite priority* at claim time. A starved
/// job (deep backlog, little in flight) climbs; a job saturating the
/// fleet sinks. The same invariant discipline as hint steering: class
/// and line order are never inverted, equal weights preserve exact
/// FIFO, and an absent or single-job map is byte-identical to the
/// unweighted path.
#[derive(Default)]
pub struct ClaimWeights {
    weights: RwLock<HashMap<u64, f64>>,
}

impl ClaimWeights {
    /// Set (or update) one job's claim weight.
    pub fn set(&self, job: u64, weight: f64) {
        self.weights.write().unwrap().insert(job, weight);
    }

    /// Drop a finished job's weight.
    pub fn clear(&self, job: u64) {
        self.weights.write().unwrap().remove(&job);
    }

    /// Fair share only means anything with at least two jobs competing
    /// — below that, weight-aware receives take the unweighted
    /// (byte-identical, early-stopping) path.
    pub fn active(&self) -> bool {
        self.weights.read().unwrap().len() >= 2
    }

    /// The claim weight of the job owning a `job_id|node_id` message
    /// body. Unparsable bodies and unknown jobs weigh the neutral 1.0,
    /// so foreign messages never lose eligibility.
    pub fn weight_of_body(&self, body: &str) -> f64 {
        let Some((id, _)) = body.split_once('|') else {
            return 1.0;
        };
        let Ok(job) = id.parse::<u64>() else {
            return 1.0;
        };
        self.weights.read().unwrap().get(&job).copied().unwrap_or(1.0)
    }
}

/// S3-like tile store: high-throughput keyed storage with per-key
/// read-after-write consistency and transfer accounting.
pub trait BlobStore: Send + Sync {
    /// Store a tile under `key`, attributed to `worker`.
    fn put(&self, worker: usize, key: &str, value: Matrix) -> Result<()>;

    /// Fetch the tile at `key`, attributed to `worker`.
    fn get(&self, worker: usize, key: &str) -> Result<Arc<Matrix>>;

    /// Does `key` exist? (No latency or accounting — control-plane op.)
    fn contains(&self, key: &str) -> bool;

    /// Delete the tile at `key`; returns whether it existed. Fallible
    /// like `put`/`get` — the chaos layer injects transient faults
    /// here too, so GC callers retry exactly as workers do.
    fn delete(&self, key: &str) -> Result<bool>;

    /// Keys starting with `prefix`, sorted. Control-plane op (no
    /// accounting) — the runtime's namespace-listing primitive, like
    /// S3 `ListObjectsV2` with a prefix.
    fn scan_prefix(&self, prefix: &str) -> Vec<String>;

    /// Bulk-delete every key under `prefix`; returns the number of
    /// objects removed (callers assert reclamation against it). The
    /// analogue of an S3 lifecycle sweep: infallible and idempotent.
    fn delete_prefix(&self, prefix: &str) -> usize;

    /// Time since the most recent `put` under `prefix` (the
    /// namespace's write-idle age), or `None` when no key matches.
    /// Only writes refresh the timestamp — reads leave it untouched,
    /// mirroring S3 `LastModified`. Control-plane op (no latency or
    /// accounting).
    fn prefix_age(&self, prefix: &str) -> Option<Duration>;

    /// Every namespace's write-idle age from **one** scan: keys are
    /// grouped by their prefix up to and including the first
    /// `delimiter` (keys without it are skipped), and each group
    /// reports the same quantity as [`BlobStore::prefix_age`] — time
    /// since its newest write. Sorted by prefix. The S3 analogue is
    /// `ListObjectsV2` with a delimiter, reading `LastModified` across
    /// each common prefix; the TTL sweeper uses this instead of one
    /// `prefix_age` call per namespace so a sweep pass costs one store
    /// walk, not one per resident namespace. Control-plane op.
    fn prefix_ages(&self, delimiter: char) -> Vec<(String, Duration)>;

    /// Number of stored objects.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Aggregate stats.
    fn stats(&self) -> StoreStats;

    /// Per-worker stats (Figure 7's per-machine bytes).
    fn worker_stats(&self, worker: usize) -> StoreStats;

    /// Ids of workers that have touched the store.
    fn known_workers(&self) -> Vec<usize>;
}

/// SQS-like task queue: at-least-once delivery with visibility-timeout
/// leases (the §4.1 fault-tolerance protocol rests on these exact
/// guarantees). Highest priority first among visible messages; ties
/// break FIFO by enqueue order.
pub trait Queue: Send + Sync {
    /// Enqueue a message.
    fn send(&self, body: &str, priority: i64);

    /// Enqueue a message carrying a **soft locality hint**: the id of
    /// the worker believed to hold this task's input tiles in its
    /// local cache (see `crate::storage::cache`). Hints never change
    /// delivery guarantees — only *which equally-eligible receiver* a
    /// hint-aware backend prefers, and only within a bounded staleness
    /// window so a slow or dead hinted worker never starves the
    /// message. Backends without affinity support (the default) drop
    /// the hint and deliver normally.
    fn send_hinted(&self, body: &str, priority: i64, hint: Option<u64>) {
        let _ = hint;
        self.send(body, priority);
    }

    /// Try to receive the best visible message; takes a lease for the
    /// queue's default lease duration. Non-blocking.
    fn receive(&self) -> Option<(String, Lease)>;

    /// [`Queue::receive`] identifying the claiming worker, so a
    /// hint-aware backend can steer hinted messages toward their
    /// preferred worker among candidates of **equal** priority.
    /// Priority order and FIFO-within-priority for unhinted messages
    /// are never violated, and a message whose hint names another
    /// worker is still delivered here once its hint ages past the
    /// staleness bound or no better candidate exists. Defaults to
    /// plain [`Queue::receive`] (hints ignored).
    fn receive_for(&self, worker: u64) -> Option<(String, Lease)> {
        let _ = worker;
        self.receive()
    }

    /// Blocking receive with timeout. Returns `None` on timeout.
    fn receive_timeout(&self, timeout: Duration) -> Option<(String, Lease)>;

    /// Blocking [`Queue::receive_for`] with timeout; same affinity
    /// semantics, same `None`-on-timeout contract as
    /// [`Queue::receive_timeout`], which is also the default.
    fn receive_timeout_for(&self, worker: u64, timeout: Duration) -> Option<(String, Lease)> {
        let _ = worker;
        self.receive_timeout(timeout)
    }

    /// Renew the lease for another lease period from now. Fails if the
    /// lease is stale (message redelivered or deleted).
    fn renew(&self, lease: &Lease) -> bool;

    /// Delete the message — only valid while holding the current lease
    /// (the §4.1 invariant: delete only after effects are durable).
    fn delete(&self, lease: &Lease) -> bool;

    /// Number of messages (visible + invisible) — the provisioner's
    /// "pending tasks" signal.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of currently-visible messages.
    fn visible_len(&self) -> usize;

    /// How many times the message body has been delivered (testing
    /// aid; at-least-once shows up as counts > 1).
    fn delivery_count(&self, body: &str) -> u32;

    /// Remove every message whose body starts with `body_prefix`,
    /// leased or not; returns the number purged. Held leases on purged
    /// messages become stale (renew/delete return false). The
    /// runtime's queue-residue drain: a finished job's messages are
    /// `jobid|…`, so one prefix purge empties its backlog without
    /// waiting for workers to receive-and-drop each one.
    fn purge_prefix(&self, body_prefix: &str) -> usize;

    /// Attach the fleet's shared per-job [`ClaimWeights`] so
    /// weight-aware backends can apply dynamic fair share at claim
    /// time (see [`ClaimWeights`]). Weights are advisory scheduling
    /// state, never delivery semantics; the default (most backends)
    /// ignores them.
    fn set_claim_weights(&self, weights: Arc<ClaimWeights>) {
        let _ = weights;
    }
}

/// Redis-like runtime state store: per-key linearizable RMW — all the
/// control-plane atomicity numpywren's protocol needs (§4 step 4).
pub trait KvState: Send + Sync {
    fn get(&self, key: &str) -> Option<String>;

    fn set(&self, key: &str, value: &str);

    /// Set iff absent. Returns true when this call created the key —
    /// the idempotence primitive (only the first caller proceeds).
    fn set_nx(&self, key: &str, value: &str) -> bool;

    /// Compare-and-swap: if current == `expect` (None = absent), set
    /// to `value` and return true.
    fn cas(&self, key: &str, expect: Option<&str>, value: &str) -> bool;

    /// Initialize a counter iff absent; returns true if this call
    /// initialized it.
    fn init_counter(&self, key: &str, value: i64) -> bool;

    /// Atomically add `delta` (counter created as 0 if absent);
    /// returns the new value.
    fn incr(&self, key: &str, delta: i64) -> i64;

    /// Atomically decrement; returns the new value.
    fn decr(&self, key: &str) -> i64 {
        self.incr(key, -1)
    }

    fn counter(&self, key: &str) -> i64;

    /// Does the counter exist (distinct from == 0)?
    fn counter_exists(&self, key: &str) -> bool;

    /// Delete `key` from the string KV *and* the counter space;
    /// returns whether anything existed under it.
    fn delete(&self, key: &str) -> bool;

    /// Keys starting with `prefix` across both the string KV and the
    /// counter space (status, deps, edge guards, counters), sorted and
    /// deduplicated.
    fn scan_prefix(&self, prefix: &str) -> Vec<String>;

    /// Bulk-delete every entry (string or counter) under `prefix`;
    /// returns the number of entries removed. A key present in both
    /// spaces counts twice — job namespaces keep the two disjoint.
    fn delete_prefix(&self, prefix: &str) -> usize;

    /// The dependency-propagation primitive: atomically, if `edge_key`
    /// has not been marked, mark it and decrement `counter_key`.
    /// Returns the counter value after the (possibly skipped)
    /// decrement. Idempotent per edge — a re-executed parent task
    /// re-observes the value instead of double-decrementing, and a
    /// worker that crashed between the decrement and the child enqueue
    /// lets its successor re-observe the 0 and enqueue (at-least-once
    /// enqueue is safe; execution is idempotent). Both keys update
    /// under one atomic step even when a backend shards them apart.
    fn edge_decr(&self, edge_key: &str, counter_key: &str) -> i64;

    /// Total operations served (control-plane load metric).
    fn op_count(&self) -> u64;
}

/// One stored object of the in-process blob backends: the tile plus
/// its last-write time — the `LastModified` analogue behind
/// [`BlobStore::prefix_age`]/[`BlobStore::prefix_ages`]. Shared so the
/// strict and sharded backends cannot drift on age semantics.
pub(crate) struct Stored {
    pub(crate) tile: Arc<Matrix>,
    pub(crate) written: Instant,
}

impl Stored {
    pub(crate) fn new(tile: Matrix) -> Stored {
        Stored {
            tile: Arc::new(tile),
            written: Instant::now(),
        }
    }
}

/// The shared [`BlobStore::prefix_ages`] kernel: fold `(key, written)`
/// observations into per-namespace write-idle minima. Keys are grouped
/// by their prefix up to and including the first `delimiter`; keys
/// without it are skipped. `finish` returns the groups sorted.
pub(crate) struct PrefixAges {
    now: Instant,
    delimiter: char,
    ages: BTreeMap<String, Duration>,
}

impl PrefixAges {
    pub(crate) fn new(delimiter: char) -> PrefixAges {
        PrefixAges {
            now: Instant::now(),
            delimiter,
            ages: BTreeMap::new(),
        }
    }

    pub(crate) fn observe(&mut self, key: &str, written: Instant) {
        let Some(end) = key.find(self.delimiter) else {
            return;
        };
        let age = self.now.saturating_duration_since(written);
        let ns = &key[..end + self.delimiter.len_utf8()];
        match self.ages.get_mut(ns) {
            Some(cur) => *cur = (*cur).min(age),
            None => {
                self.ages.insert(ns.to_string(), age);
            }
        }
    }

    pub(crate) fn finish(self) -> Vec<(String, Duration)> {
        self.ages.into_iter().collect()
    }
}

/// Byte/op counters shared by the blob-store backends.
#[derive(Default)]
pub(crate) struct Counters {
    pub(crate) bytes_read: AtomicU64,
    pub(crate) bytes_written: AtomicU64,
    pub(crate) get_ops: AtomicU64,
    pub(crate) put_ops: AtomicU64,
}

impl Counters {
    pub(crate) fn snapshot(&self) -> StoreStats {
        StoreStats {
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            get_ops: self.get_ops.load(Ordering::Relaxed),
            put_ops: self.put_ops.load(Ordering::Relaxed),
        }
    }
}

/// Totals + per-worker transfer accounting (Figure 7), shared by the
/// blob-store backends. Counter bumps are lock-free; the per-worker
/// map takes its write lock only on a worker's first operation.
#[derive(Default)]
pub(crate) struct TransferAccounting {
    totals: Counters,
    per_worker: RwLock<HashMap<usize, Arc<Counters>>>,
}

impl TransferAccounting {
    fn worker_counters(&self, worker: usize) -> Arc<Counters> {
        if let Some(c) = self.per_worker.read().unwrap().get(&worker) {
            return c.clone();
        }
        let mut w = self.per_worker.write().unwrap();
        w.entry(worker).or_default().clone()
    }

    pub(crate) fn record_get(&self, worker: usize, bytes: u64) {
        self.totals.bytes_read.fetch_add(bytes, Ordering::Relaxed);
        self.totals.get_ops.fetch_add(1, Ordering::Relaxed);
        let wc = self.worker_counters(worker);
        wc.bytes_read.fetch_add(bytes, Ordering::Relaxed);
        wc.get_ops.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_put(&self, worker: usize, bytes: u64) {
        self.totals.bytes_written.fetch_add(bytes, Ordering::Relaxed);
        self.totals.put_ops.fetch_add(1, Ordering::Relaxed);
        let wc = self.worker_counters(worker);
        wc.bytes_written.fetch_add(bytes, Ordering::Relaxed);
        wc.put_ops.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn stats(&self) -> StoreStats {
        self.totals.snapshot()
    }

    pub(crate) fn worker_stats(&self, worker: usize) -> StoreStats {
        match self.per_worker.read().unwrap().get(&worker) {
            Some(c) => c.snapshot(),
            None => StoreStats::default(),
        }
    }

    pub(crate) fn known_workers(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.per_worker.read().unwrap().keys().copied().collect();
        v.sort_unstable();
        v
    }
}
