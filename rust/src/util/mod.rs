//! Small shared utilities: deterministic PRNG, timing helpers, and a
//! tiny property-testing harness (the offline crate set has neither
//! `rand` nor `proptest`, so we carry our own).

pub mod prng;
pub mod proptest;
pub mod timer;

pub use prng::Rng;
pub use timer::Stopwatch;
