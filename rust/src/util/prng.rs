//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** seeded via splitmix64 — the standard small, fast,
//! statistically solid generator. Deterministic seeds keep every test,
//! example, and benchmark reproducible run-to-run, which matters for
//! the paper-figure benches (error bars come from seed sweeps, not from
//! nondeterminism).

/// A xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free-enough for test use.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform i64 in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (one value per call; simple, fine
    /// for test-matrix generation).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..64).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }
}
