//! A miniature property-testing harness.
//!
//! The offline crate set carries neither `proptest` nor `quickcheck`,
//! so we provide the 5% of the idea that the coordinator-invariant
//! tests need: run a property over many deterministic random cases and,
//! on failure, report the seed + case index so the exact case replays.

use crate::util::prng::Rng;

/// Number of cases `forall` runs by default (override with the
/// `NUMPYWREN_PROPTEST_CASES` env var).
pub fn default_cases() -> usize {
    std::env::var("NUMPYWREN_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop(rng, case_index)` over `cases` deterministic cases.
/// `prop` returns `Err(msg)` to fail the property; panics propagate
/// with seed/case attribution too.
pub fn forall<F>(name: &str, seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cases {
        // Derive a fresh generator per case so a failing case replays
        // in isolation: Rng::new(seed ^ case-hash).
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng, case) {
            panic!("property `{name}` failed at case {case} (seed {case_seed:#x}): {msg}");
        }
    }
}

/// Convenience macro: `prop_assert!(cond, "msg {}", x)` inside a
/// `forall` body returns an Err instead of panicking.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// `prop_assert_eq!(a, b)` — equality with value printing.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("u64 xor is involutive", 42, 32, |rng, _| {
            let a = rng.next_u64();
            let b = rng.next_u64();
            prop_assert_eq!(a ^ b ^ b, a);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn forall_reports_failure() {
        forall("always fails", 1, 4, |_, _| Err("nope".into()));
    }
}
