//! Wall-clock timing helpers for the engine, examples, and the
//! hand-rolled bench harness (criterion is not in the offline crate
//! set; `cargo bench` targets use `harness = false` and these helpers).

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Run `f` `iters` times and return (total, per-iter) durations.
pub fn time_n<F: FnMut()>(iters: usize, mut f: F) -> (Duration, Duration) {
    let sw = Stopwatch::start();
    for _ in 0..iters {
        f();
    }
    let total = sw.elapsed();
    (total, total / iters.max(1) as u32)
}

/// Median-of-runs micro-bench: runs `f` until `min_time` has elapsed or
/// `max_iters` reached, returns (iters, median seconds/iter).
/// Used by the `benches/` targets for stable per-row numbers.
pub fn bench_median<F: FnMut()>(min_time: Duration, max_iters: usize, mut f: F) -> (usize, f64) {
    let mut samples = Vec::new();
    let overall = Stopwatch::start();
    while samples.len() < 3 || (overall.elapsed() < min_time && samples.len() < max_iters) {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.secs());
        if samples.len() >= max_iters {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    (samples.len(), median)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        let a = sw.secs();
        let b = sw.secs();
        assert!(b >= a);
    }

    #[test]
    fn time_n_counts() {
        let mut n = 0usize;
        let (_, _) = time_n(10, || n += 1);
        assert_eq!(n, 10);
    }

    #[test]
    fn bench_median_runs_at_least_three() {
        let mut n = 0usize;
        let (iters, med) = bench_median(Duration::from_millis(1), 5, || n += 1);
        assert!(iters >= 3 && iters <= 5);
        assert!(med >= 0.0);
        assert_eq!(n, iters);
    }
}
