//! End-to-end tests for the long-lived daemon mode (`numpywren
//! serve`) and the TTL namespace sweeper.
//!
//! The daemon tests run the serve loop on its own thread and drive it
//! the way a second process would: through the file-spool wire
//! protocol only (`DaemonClient` writes `cmd/*.json`, polls
//! `rsp/*.json`). Nothing in the client half touches the `JobManager`
//! directly, so these are genuine wire-format round-trips. The TTL
//! tests pin the sweeper's contract at the `JobManager` level:
//! expired namespaces are reclaimed, pinned namespaces are immune
//! until their last chain consumer is terminal, and the sweep holds
//! under chaos fault injection.

use numpywren::config::{EngineConfig, RetentionPolicy, ScalingMode, SubstrateConfig};
use numpywren::daemon::{Daemon, DaemonClient};
use numpywren::drivers;
use numpywren::jobs::{JobId, JobManager, JobSpec};
use numpywren::lambdapack::programs;
use numpywren::linalg::matrix::Matrix;
use numpywren::storage::{BlobStore as _, KvState as _};
use numpywren::util::prng::Rng;
use std::path::PathBuf;
use std::time::{Duration, Instant};

const RPC: Duration = Duration::from_secs(30);
const JOB_WAIT: Duration = Duration::from_secs(120);

/// A fresh spool directory per test (tests run in parallel).
fn spool(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("npw_daemon_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn fleet_cfg(workers: usize) -> EngineConfig {
    EngineConfig {
        scaling: ScalingMode::Fixed(workers),
        job_timeout: Duration::from_secs(120),
        ..EngineConfig::default()
    }
}

fn tiny_cholesky_spec(n: usize, seed: u64) -> JobSpec {
    let mut rng = Rng::new(seed);
    let a = Matrix::rand_spd(n, &mut rng);
    let (env, inputs, _grid) = drivers::stage_cholesky(&a, 8).unwrap();
    JobSpec::new(programs::cholesky_spec().program, env, inputs).with_outputs(["O"])
}

/// Poll until the manager's substrate holds nothing under `prefix`.
fn wait_reclaimed(mgr: &JobManager, prefix: &str, deadline: Duration) -> bool {
    let end = Instant::now() + deadline;
    while Instant::now() < end {
        if mgr.store().scan_prefix(prefix).is_empty() && mgr.state().scan_prefix(prefix).is_empty()
        {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    false
}

// ------------------------------------------------------------------
// Daemon wire protocol
// ------------------------------------------------------------------

#[test]
fn daemon_serves_two_job_chain_over_the_wire() {
    // The acceptance scenario: a client submits a 2-job chain through
    // the spool dir, the daemon runs it on one shared fleet, `status`
    // round-trips, and a later request chains onto an existing daemon
    // job with `@jN`.
    let dir = spool("chain");
    let daemon = Daemon::new(fleet_cfg(3), &dir).unwrap();
    let server = std::thread::spawn(move || daemon.run());
    let client = DaemonClient::new(&dir);

    let baseline = client.stats(RPC).unwrap();
    assert_eq!(baseline.resident(), 0, "fresh substrate");
    assert_eq!(baseline.active, 0);
    // One daemon per spool dir: a second claim on a dir whose marker
    // names a live pid (ours) is refused instead of double-executing
    // everything. The liveness probe is /proc-based, so the guarantee
    // (and this assertion) is Linux-only.
    if cfg!(target_os = "linux") {
        let second = Daemon::new(fleet_cfg(1), &dir);
        assert!(second.is_err(), "second daemon on a live spool must be refused");
    }

    let jobs = client.submit("cholesky:16:8,gemm:16:8:1@1", 7, None, None, RPC).unwrap();
    assert_eq!(jobs, vec![JobId(1), JobId(2)]);
    // Status round-trips for every lifecycle phase we can catch: any
    // of waiting/running/succeeded is legal while the chain drains,
    // and both must land on succeeded.
    let early = client.status(jobs[1], RPC).unwrap();
    assert!(
        matches!(early.state.as_str(), "waiting" | "running" | "succeeded"),
        "unexpected state {}",
        early.state
    );
    for job in &jobs {
        let st = client.wait_terminal(*job, JOB_WAIT).unwrap();
        assert_eq!(st.state, "succeeded", "{job}: {:?}", st.error);
    }
    // Terminal jobs are not cancelable.
    assert!(!client.cancel(jobs[0], RPC).unwrap());
    // A second request (another shell, in real use) chains onto the
    // first request's gemm by daemon job id.
    let chained = client.submit("gemm:16:8@j2", 11, None, None, RPC).unwrap();
    assert_eq!(chained, vec![JobId(3)]);
    let st = client.wait_terminal(chained[0], JOB_WAIT).unwrap();
    assert_eq!(st.state, "succeeded", "{:?}", st.error);

    let after = client.stats(RPC).unwrap();
    assert_eq!(after.active, 0, "all jobs terminal");
    assert!(after.blobs > 0, "KeepAll namespaces stay resident");

    client.shutdown(RPC).unwrap();
    let fleet = server.join().unwrap().unwrap();
    assert_eq!(fleet.workers_spawned, 3);
    assert!(!dir.join("daemon.json").exists(), "marker removed");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn daemon_ttl_sweeper_reclaims_to_baseline_over_the_wire() {
    // KeepAll jobs + the TTL sweeper: once the namespace goes
    // write-idle past the TTL, the daemon returns to substrate
    // baseline — the unbounded-uptime story, asserted via `stats`
    // round-trips only.
    let dir = spool("ttl");
    let mut cfg = fleet_cfg(2);
    cfg.gc.ttl = Some(Duration::from_millis(250));
    cfg.gc.sweep_interval = Duration::from_millis(10);
    let daemon = Daemon::new(cfg, &dir).unwrap();
    let server = std::thread::spawn(move || daemon.run());
    let client = DaemonClient::new(&dir);

    let jobs = client.submit("cholesky:16:8,cholesky:16:8", 3, None, None, RPC).unwrap();
    for job in &jobs {
        let st = client.wait_terminal(*job, JOB_WAIT).unwrap();
        assert_eq!(st.state, "succeeded", "{:?}", st.error);
    }
    let resident = client.stats(RPC).unwrap();
    assert!(resident.blobs > 0, "namespaces resident before expiry");
    let deadline = Instant::now() + Duration::from_secs(30);
    let drained = loop {
        let s = client.stats(RPC).unwrap();
        if s.resident() == 0 {
            break true;
        }
        if Instant::now() >= deadline {
            break false;
        }
        std::thread::sleep(Duration::from_millis(10));
    };
    assert!(drained, "TTL sweeper must return the substrate to baseline");
    // The swept service still takes new work.
    let again = client.submit("cholesky:16:8", 5, None, None, RPC).unwrap();
    let st = client.wait_terminal(again[0], JOB_WAIT).unwrap();
    assert_eq!(st.state, "succeeded", "{:?}", st.error);

    client.shutdown(RPC).unwrap();
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn daemon_error_paths_over_the_wire() {
    let dir = spool("errors");
    let daemon = Daemon::new(fleet_cfg(1), &dir).unwrap();
    let server = std::thread::spawn(move || daemon.run());
    let client = DaemonClient::new(&dir);

    // Unsupported algo, malformed spec, and forward chain reference
    // come back as protocol errors, not daemon deaths.
    for bad in ["tsqr:16:8", "cholesky:16", "gemm:16:8@1", "gemm:16:8@j99"] {
        assert!(
            client.submit(bad, 1, None, None, RPC).is_err(),
            "`{bad}` must be rejected over the wire"
        );
    }
    // All-or-nothing validation: a bad trailing spec must not leave
    // the leading cholesky running under an id the client never got —
    // with KeepAll retention and no TTL, any submitted job would leave
    // blob residue behind.
    assert!(client.submit("cholesky:16:8,gemm:24:8@1", 1, None, None, RPC).is_err());
    assert_eq!(client.stats(RPC).unwrap().blobs, 0, "nothing was submitted");
    // Quota 0 is wire-rejected (it would park the job forever).
    assert!(client.submit("cholesky:16:8", 1, None, Some(0), RPC).is_err());
    // Unknown jobs: status says unknown, cancel declines.
    assert_eq!(client.status(JobId(99), RPC).unwrap().state, "unknown");
    assert!(!client.cancel(JobId(99), RPC).unwrap());
    assert!(client.wait_terminal(JobId(99), RPC).is_err());
    // A file that is not even JSON gets an ok=false response too.
    std::fs::write(dir.join("cmd").join("zzz-garbage.json"), "not json").unwrap();
    let rsp = dir.join("rsp").join("zzz-garbage.json");
    let end = Instant::now() + RPC;
    while !rsp.exists() && Instant::now() < end {
        std::thread::sleep(Duration::from_millis(2));
    }
    let body = std::fs::read_to_string(&rsp).unwrap();
    assert!(body.contains("\"ok\":false"), "{body}");
    // The daemon survives all of the above and still runs real work.
    let jobs = client.submit("cholesky:16:8", 2, None, None, RPC).unwrap();
    let st = client.wait_terminal(jobs[0], JOB_WAIT).unwrap();
    assert_eq!(st.state, "succeeded", "{:?}", st.error);

    client.shutdown(RPC).unwrap();
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cli_serve_submit_status_shutdown_roundtrip() {
    // The CLI surface end-to-end: `serve` on one thread, the client
    // commands driven exactly as a second shell would invoke them.
    let dir = spool("cli");
    let dirs = dir.display().to_string();
    let argv = |s: &str| -> Vec<String> { s.split_whitespace().map(|x| x.to_string()).collect() };
    let serve_args = argv(&format!("serve --daemon-dir {dirs} --workers 2"));
    let server = std::thread::spawn(move || numpywren::cli::run_cli(&serve_args));
    numpywren::cli::run_cli(&argv(&format!(
        "submit --daemon-dir {dirs} --specs cholesky:16:8,gemm:16:8@1 --seed 9 --wait true"
    )))
    .unwrap();
    numpywren::cli::run_cli(&argv(&format!("status --daemon-dir {dirs} --job j1"))).unwrap();
    numpywren::cli::run_cli(&argv(&format!("cancel --daemon-dir {dirs} --job j1"))).unwrap();
    numpywren::cli::run_cli(&argv(&format!("shutdown --daemon-dir {dirs}"))).unwrap();
    server.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------------------
// TTL sweeper contracts (JobManager level)
// ------------------------------------------------------------------

#[test]
fn ttl_sweeper_spares_pinned_namespace_until_pins_release() {
    let mut cfg = fleet_cfg(2);
    cfg.gc.ttl = Some(Duration::from_millis(150));
    cfg.gc.sweep_interval = Duration::from_millis(5);
    let mgr = JobManager::new(cfg);
    // p1: a finished KeepAll parent whose outputs a gated child
    // imports.
    let p1 = mgr.submit(tiny_cholesky_spec(16, 21)).unwrap();
    let r1 = mgr.wait(p1).unwrap();
    assert_eq!(r1.completed, r1.total_tasks);
    // blocker: quota 0 means no worker ever claims a task — the job
    // runs "forever", keeping the child gated deterministically.
    let blocker = mgr.submit(tiny_cholesky_spec(16, 22).with_max_inflight(0)).unwrap();
    let mut rng = Rng::new(23);
    let b = Matrix::randn(16, 16, &mut rng);
    let (env, inputs, imports, _grid) = drivers::stage_gemm_after_cholesky(p1, &b, 8).unwrap();
    let child = mgr
        .submit_after(
            JobSpec::new(programs::gemm_spec().program, env, inputs)
                .with_outputs(["Ctmp"])
                .with_imports(imports),
            &[p1, blocker],
        )
        .unwrap();
    // p1's namespace ages far past the TTL while the child still pins
    // it: the sweeper must not touch a pinned namespace.
    std::thread::sleep(Duration::from_millis(500));
    assert!(
        mgr.tile(p1, "O", &[0, 0]).is_ok(),
        "pinned namespace reclaimed under the consumer"
    );
    // Release the gate: canceling the blocker seals the child failed,
    // which drops its pins on p1 — now the TTL pass may collect.
    assert!(mgr.cancel(blocker));
    let rc = mgr.wait(child).unwrap();
    assert!(rc.error.unwrap().contains("upstream"), "child sealed by gate");
    assert!(
        wait_reclaimed(&mgr, "j1/", Duration::from_secs(30)),
        "unpinned expired namespace must be reclaimed"
    );
    // The blocker's own namespace expires too once it is terminal.
    assert!(wait_reclaimed(&mgr, "j2/", Duration::from_secs(30)));
    let _ = mgr.shutdown();
}

#[test]
fn ttl_sweep_reclaims_trimmed_keepoutputs_under_chaos() {
    // Chaos leg: transient blob faults hit the job's own I/O *and*
    // the GC trim's single-key deletes; the sweep must retry through
    // them and the TTL pass must still reach substrate baseline.
    let mut cfg = fleet_cfg(2);
    cfg.substrate = SubstrateConfig::parse("sharded:4+chaos(err=0.15,seed=11)").unwrap();
    cfg.gc.ttl = Some(Duration::from_millis(200));
    cfg.gc.sweep_interval = Duration::from_millis(10);
    let mgr = JobManager::new(cfg);
    let job = mgr
        .submit(tiny_cholesky_spec(16, 31).with_retention(RetentionPolicy::KeepOutputs))
        .unwrap();
    let r = mgr.wait(job).unwrap();
    assert_eq!(r.completed, r.total_tasks);
    assert!(r.error.is_none());
    // Stage 1 trims the namespace to its declared outputs (retried
    // under err=); the TTL pass then expires the parked outputs.
    assert!(
        wait_reclaimed(&mgr, "j1/", Duration::from_secs(30)),
        "TTL must reclaim the parked KeepOutputs namespace under chaos"
    );
    // And the substrate still works: run another job to completion.
    let again = mgr.submit(tiny_cholesky_spec(16, 32)).unwrap();
    assert!(mgr.wait(again).unwrap().error.is_none());
    let _ = mgr.shutdown();
}
