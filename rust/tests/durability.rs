//! Durability tests for the `file:` substrate family: real processes,
//! real kill -9, state shared through nothing but the directory.
//!
//! The paper's claim (§3) is that a serverless runtime survives the
//! death of any component because all state lives in durable services.
//! These tests pin that claim on the reproduction:
//!
//! * a daemon killed -9 mid-chain restarts, re-attaches the surviving
//!   `jN/` namespaces, and completes the chain with numerics identical
//!   to an uninterrupted run,
//! * a second *process* (`numpywren worker`) joins the daemon's fleet
//!   over the shared directory,
//! * queue leases live in files, so they survive process death and
//!   expire by wall clock.

use numpywren::config::{RetentionPolicy, SubstrateConfig};
use numpywren::daemon::DaemonClient;
use numpywren::storage::Substrate;
use numpywren::JobId;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

const BIN: &str = env!("CARGO_BIN_EXE_numpywren");
const RPC: Duration = Duration::from_secs(60);

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("npw_durability_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Kills the child on drop so a failing assert never leaks a daemon.
struct Reaper(Child);

impl Drop for Reaper {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn spawn_serve(spool: &Path, substrate: &Path, workers: usize) -> Reaper {
    spawn_serve_with(spool, substrate, workers, &[])
}

/// `spawn_serve` plus extra CLI args (e.g. `--set store_latency=…` to
/// stretch task durations so a kill lands genuinely mid-task).
fn spawn_serve_with(spool: &Path, substrate: &Path, workers: usize, extra: &[&str]) -> Reaper {
    let child = Command::new(BIN)
        .args([
            "serve",
            "--daemon-dir",
            &spool.display().to_string(),
            "--substrate",
            &format!("file:{}", substrate.display()),
            "--workers",
            &workers.to_string(),
            "--retention",
            "keep",
        ])
        .args(extra)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning numpywren serve");
    Reaper(child)
}

/// Poll `status` until the daemon answers, tolerating the restart
/// window where the predecessor's marker still names a dead pid.
fn status_when_up(
    client: &DaemonClient,
    job: JobId,
    deadline: Instant,
) -> numpywren::daemon::StatusReply {
    loop {
        match client.status(job, Duration::from_secs(5)) {
            Ok(st) => return st,
            Err(e) => {
                assert!(Instant::now() < deadline, "daemon never came up: {e:#}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Submit under KeepAll retention (the namespaces must survive for
/// the post-mortem tile comparison).
fn submit_keep(
    client: &DaemonClient,
    specs: &str,
    seed: u64,
    max_inflight: Option<usize>,
) -> Vec<JobId> {
    let keep = Some(RetentionPolicy::KeepAll);
    client.submit(specs, seed, keep, max_inflight, RPC).unwrap()
}

fn wait_succeeded(client: &DaemonClient, jobs: &[JobId]) {
    for job in jobs {
        let st = client.wait_terminal(*job, Duration::from_secs(300)).unwrap();
        assert_eq!(st.state, "succeeded", "{job}: {:?}", st.error);
    }
}

fn open_substrate(dir: &Path) -> Substrate {
    let cfg = SubstrateConfig::parse(&format!("file:{}", dir.display())).unwrap();
    Substrate::build(&cfg, Duration::from_secs(10), Duration::ZERO)
}

/// All blob keys in the directory, sorted (tiles only — KV and queue
/// residue are asserted separately).
fn blob_keys(sub: &Substrate) -> Vec<String> {
    let mut keys = sub.blob.scan_prefix("");
    keys.sort_unstable();
    keys
}

/// kill -9 a daemon mid-chain; a fresh daemon on the same directory
/// must finish the chain bit-exactly. The ISSUE acceptance test.
#[cfg(target_os = "linux")]
#[test]
fn daemon_killed_mid_chain_restarts_and_completes_bit_exactly() {
    let spool = tmpdir("kill_spool");
    let store = tmpdir("kill_store");
    let specs = "cholesky:48:8,gemm:48:8@1";
    let seed = 7u64;

    let first = spawn_serve(&spool, &store, 1);
    let client = DaemonClient::new(&spool);
    // max_inflight=1 serializes the tasks, so the run is long enough
    // to be killed while genuinely mid-chain.
    let jobs = submit_keep(&client, specs, seed, Some(1));
    assert_eq!(jobs.len(), 2);

    // Wait for real progress, then kill -9. Should the tiny chain win
    // the race and finish first, the restart still exercises recovery
    // of completed jobs — but with one worker and a serialized queue
    // that never happens in practice.
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let st = status_when_up(&client, jobs[0], deadline);
        if (st.state == "running" && st.completed >= 2) || st.is_terminal() {
            break;
        }
        assert!(Instant::now() < deadline, "j1 never progressed");
        std::thread::sleep(Duration::from_millis(5));
    }
    drop(first); // SIGKILL: no drain, no marker cleanup, leases left behind

    // The dead daemon's marker is detected, not polled against.
    let err = client.status(jobs[0], Duration::from_secs(5)).unwrap_err().to_string();
    assert!(err.contains("dead"), "{err}");

    // Restart against the same directories: the marker is reclaimed,
    // the spool and the `jN/` manifests recovered, and the chain runs
    // to completion.
    let second = spawn_serve(&spool, &store, 2);
    status_when_up(&client, jobs[0], Instant::now() + Duration::from_secs(60));
    wait_succeeded(&client, &jobs);
    client.shutdown(Duration::from_secs(30)).unwrap();
    drop(second);

    // Reference: the same submission, uninterrupted, on fresh dirs.
    let ref_spool = tmpdir("ref_spool");
    let ref_store = tmpdir("ref_store");
    let reference = spawn_serve(&ref_spool, &ref_store, 2);
    let ref_client = DaemonClient::new(&ref_spool);
    let ref_jobs = submit_keep(&ref_client, specs, seed, None);
    wait_succeeded(&ref_client, &ref_jobs);
    ref_client.shutdown(Duration::from_secs(30)).unwrap();
    drop(reference);

    // Exact numerics: every tile either run produced, bit-for-bit.
    // (Inputs regenerate from the manifest's derived seed; kernels and
    // the reduction shape are deterministic, so even tiles recomputed
    // after redelivery must match exactly.)
    let survived = open_substrate(&store);
    let ref_sub = open_substrate(&ref_store);
    let keys = blob_keys(&survived);
    assert_eq!(keys, blob_keys(&ref_sub), "tile sets diverged");
    assert!(!keys.is_empty());
    for key in &keys {
        assert!(
            key.starts_with("j1/") || key.starts_with("j2/"),
            "leaked namespace: {key}"
        );
        let a = survived.blob.get(0, key).unwrap();
        let b = ref_sub.blob.get(0, key).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.0, "{key} not bit-exact");
    }
    // No queue residue or orphan leases: every message was deleted
    // under a valid lease.
    assert_eq!(survived.queue.len(), 0);

    for d in [&spool, &store, &ref_spool, &ref_store] {
        std::fs::remove_dir_all(d).unwrap();
    }
}

/// Two processes, one fleet: an external `numpywren worker` attaches
/// to the daemon's jobs through nothing but the shared directory.
#[test]
fn external_worker_process_joins_a_daemon_fleet() {
    let spool = tmpdir("fleet_spool");
    let store = tmpdir("fleet_store");

    let daemon = spawn_serve(&spool, &store, 1);
    let worker = Command::new(BIN)
        .args([
            "worker",
            "--substrate",
            &format!("file:{}", store.display()),
            "--workers",
            "2",
            "--idle-exit",
            "2",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning numpywren worker");

    let client = DaemonClient::new(&spool);
    let jobs = submit_keep(&client, "cholesky:32:8", 42, None);
    wait_succeeded(&client, &jobs);
    client.shutdown(Duration::from_secs(30)).unwrap();
    drop(daemon);

    // The worker saw the manifest appear (the kept namespace outlives
    // the daemon, so even a slow attach observes it) and then detached
    // cleanly once the queue went quiet.
    let out = worker.wait_with_output().unwrap();
    assert!(out.status.success(), "worker exited with {:?}", out.status);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("attached j1"), "worker never attached:\n{stdout}");
    assert!(stdout.contains("detached"), "worker never detached:\n{stdout}");

    for d in [&spool, &store] {
        std::fs::remove_dir_all(d).unwrap();
    }
}

/// kill -9 an external `numpywren worker` mid-task: the tasks it was
/// holding stay leased in the file queue, expire by wall clock, and
/// redeliver to the daemon's surviving worker — the job completes with
/// tiles bit-identical to an uninterrupted run. This is the worker-side
/// complement of the daemon kill test above: here the *submitting*
/// process survives and a fleet member dies.
#[cfg(target_os = "linux")]
#[test]
fn external_worker_killed_mid_task_redelivers_bit_exactly() {
    let spool = tmpdir("wkill_spool");
    let store = tmpdir("wkill_store");
    let specs = "cholesky:48:8";
    let seed = 11u64;
    // Stretch every store op so tasks take tens of milliseconds: the
    // SIGKILL below lands while a task is genuinely in flight, and the
    // 0.5 s default lease expires long before the job could finish
    // without redelivery.
    let latency = ["--set", "store_latency=0.005"];

    let daemon = spawn_serve_with(&spool, &store, 1, &latency);
    let mut worker = Reaper(
        Command::new(BIN)
            .args([
                "worker",
                "--substrate",
                &format!("file:{}", store.display()),
                "--workers",
                "2",
                "--idle-exit",
                "30",
            ])
            .args(latency)
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning numpywren worker"),
    );
    // Give the worker's manifest watcher a head start so it attaches
    // before the daemon's single worker can finish the early chain.
    std::thread::sleep(Duration::from_millis(300));

    let client = DaemonClient::new(&spool);
    // max_inflight=2 keeps both processes busy without letting the
    // run finish too quickly to be killed mid-task.
    let jobs = submit_keep(&client, specs, seed, Some(2));

    // Wait for real progress, then SIGKILL the external worker. Its
    // leased messages are files in the shared directory; nothing
    // cleans them up, so completion *requires* lease expiry.
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let st = status_when_up(&client, jobs[0], deadline);
        if (st.state == "running" && st.completed >= 4) || st.is_terminal() {
            break;
        }
        assert!(Instant::now() < deadline, "j1 never progressed");
        std::thread::sleep(Duration::from_millis(5));
    }
    worker.0.kill().unwrap(); // SIGKILL: leases left behind
    worker.0.wait().unwrap();

    wait_succeeded(&client, &jobs);
    client.shutdown(Duration::from_secs(30)).unwrap();
    drop(daemon);

    // The dead worker had really joined the fleet before dying (its
    // attach line flushed per-println, so SIGKILL cannot have eaten it).
    let mut stdout = String::new();
    use std::io::Read as _;
    worker.0.stdout.take().unwrap().read_to_string(&mut stdout).unwrap();
    assert!(stdout.contains("attached j1"), "worker never attached:\n{stdout}");

    // Reference: the same submission, uninterrupted, on fresh dirs.
    let ref_spool = tmpdir("wkill_ref_spool");
    let ref_store = tmpdir("wkill_ref_store");
    let reference = spawn_serve(&ref_spool, &ref_store, 2);
    let ref_client = DaemonClient::new(&ref_spool);
    let ref_jobs = submit_keep(&ref_client, specs, seed, None);
    wait_succeeded(&ref_client, &ref_jobs);
    ref_client.shutdown(Duration::from_secs(30)).unwrap();
    drop(reference);

    // Exact numerics: tasks redelivered after the kill recompute the
    // same SSA tiles bit-for-bit, so both directories hold identical
    // tile sets.
    let survived = open_substrate(&store);
    let ref_sub = open_substrate(&ref_store);
    let keys = blob_keys(&survived);
    assert_eq!(keys, blob_keys(&ref_sub), "tile sets diverged");
    assert!(!keys.is_empty());
    for key in &keys {
        let a = survived.blob.get(0, key).unwrap();
        let b = ref_sub.blob.get(0, key).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.0, "{key} not bit-exact");
    }
    // Every message — including the dead worker's redelivered leases —
    // was eventually deleted under a valid lease.
    assert_eq!(survived.queue.len(), 0);

    for d in [&spool, &store, &ref_spool, &ref_store] {
        std::fs::remove_dir_all(d).unwrap();
    }
}

/// The lease contract that makes all of the above safe: a lease taken
/// by one handle survives the handle's death (it is a file), blocks
/// redelivery until its wall-clock deadline, then redelivers — and the
/// dead holder's receipt is useless afterwards.
#[test]
fn file_queue_leases_survive_process_death() {
    let dir = tmpdir("lease");
    let cfg = SubstrateConfig::parse(&format!("file:{}", dir.display())).unwrap();
    let lease_len = Duration::from_millis(300);

    let first = Substrate::build(&cfg, lease_len, Duration::ZERO);
    first.queue.send("task-1", 0);
    let (body, dead_lease) = first.queue.receive().unwrap();
    assert_eq!(body, "task-1");
    drop(first); // the "process" dies holding the lease

    // A fresh handle on the directory sees the message leased, not
    // lost: present but invisible until the deadline passes.
    let second = Substrate::build(&cfg, lease_len, Duration::ZERO);
    assert_eq!(second.queue.len(), 1);
    assert_eq!(second.queue.visible_len(), 0);

    std::thread::sleep(lease_len + Duration::from_millis(150));
    assert_eq!(second.queue.visible_len(), 1, "lease never expired");
    let (body, live_lease) = second.queue.receive().unwrap();
    assert_eq!(body, "task-1");
    assert_eq!(second.queue.delivery_count("task-1"), 2);

    // The dead holder's receipt is stale: it can neither extend nor
    // delete out from under the new holder.
    assert!(!second.queue.renew(&dead_lease));
    assert!(!second.queue.delete(&dead_lease));
    assert!(second.queue.renew(&live_lease));
    assert!(second.queue.delete(&live_lease));
    assert_eq!(second.queue.len(), 0);

    std::fs::remove_dir_all(&dir).unwrap();
}
