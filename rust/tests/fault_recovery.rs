//! Deterministic fault-recovery tests: the §4.1 protocol driven by a
//! [`TestClock`], so lease expiry, redelivery, and exactly-once
//! *completion* (under at-least-once *delivery*) are proven without
//! wall-clock sleeps or timing luck.

use numpywren::config::SubstrateConfig;
use numpywren::linalg::matrix::Matrix;
use numpywren::storage::{
    chaos::is_transient, status, BlobStore as _, KvState as _, Queue as _, Substrate, TestClock,
};
use std::sync::Arc;
use std::time::Duration;

const LEASE: Duration = Duration::from_secs(10);

fn substrate(spec: &str) -> (Substrate, Arc<TestClock>) {
    let clock = Arc::new(TestClock::default());
    let cfg = SubstrateConfig::parse(spec).unwrap();
    let sub = Substrate::build_with_clock(&cfg, LEASE, Duration::ZERO, clock.clone());
    (sub, clock)
}

/// The §4.1 completion protocol a worker runs after executing a task:
/// durable effects first (tile write), then the status CAS (exactly
/// one winner owns the completion accounting), then delete-by-lease.
fn complete_task(
    sub: &Substrate,
    worker: usize,
    task: &str,
    lease: &numpywren::storage::Lease,
) -> bool {
    sub.blob
        .put(worker, &format!("out:{task}"), Matrix::eye(2))
        .unwrap();
    let won = sub.state.cas(&format!("status:{task}"), None, status::COMPLETED);
    if won {
        sub.state.incr("completed_total", 1);
    }
    sub.queue.delete(lease);
    won
}

#[test]
fn dead_worker_mid_lease_task_reexecuted_exactly_once_to_completion() {
    // The satellite acceptance test: a worker "dies" mid-lease; the
    // task must be re-executed by a second worker and counted complete
    // exactly once — on every backend family, chaos-wrapped included.
    for spec in ["strict", "sharded:4", "sharded:4+chaos(seed=7)"] {
        let (sub, clock) = substrate(spec);
        sub.queue.send("chol@i=0", 0);

        // Worker 1 takes the lease, does partial work, and dies: it
        // never renews or deletes — the lease just lapses.
        let (body, _lease1) = sub.queue.receive().unwrap();
        assert_eq!(body, "chol@i=0", "[{spec}]");
        assert!(sub.queue.receive().is_none(), "[{spec}] invisible while leased");

        // Failure detection latency is the visibility timeout (§4.1):
        // one tick before expiry the task is still invisible.
        clock.advance(LEASE - Duration::from_millis(1));
        assert!(
            sub.queue.receive().is_none(),
            "[{spec}] not yet redeliverable before the lease expires"
        );
        clock.advance(Duration::from_millis(1001));

        // Worker 2 gets the redelivery and completes the protocol.
        let (body, lease2) = sub.queue.receive().unwrap();
        assert_eq!(body, "chol@i=0", "[{spec}]");
        assert_eq!(sub.queue.delivery_count("chol@i=0"), 2, "[{spec}]");
        assert!(complete_task(&sub, 2, &body, &lease2), "[{spec}] CAS winner");

        // Exactly once to completion: the counter is 1, the queue is
        // empty, and no amount of further waiting redelivers.
        assert_eq!(sub.state.counter("completed_total"), 1, "[{spec}]");
        assert!(sub.queue.is_empty(), "[{spec}]");
        clock.advance(LEASE * 4);
        assert!(sub.queue.receive().is_none(), "[{spec}] nothing left");
    }
}

#[test]
fn straggler_resurrection_after_completion_cannot_double_complete() {
    // Worker 1 is *slow*, not dead: its lease expires, worker 2
    // re-executes and completes, then worker 1 wakes back up and
    // finishes its stale copy. The CAS and the stale lease make the
    // resurrection a no-op.
    let (sub, clock) = substrate("strict");
    sub.queue.send("t", 0);
    let (_, stale_lease) = sub.queue.receive().unwrap();
    clock.advance(LEASE + Duration::from_secs(1));
    let (body, fresh_lease) = sub.queue.receive().unwrap();
    assert!(complete_task(&sub, 2, &body, &fresh_lease));

    // The resurrected worker 1 replays the protocol with stale state.
    assert!(
        !complete_task(&sub, 1, "t", &stale_lease),
        "stale completer must lose the CAS"
    );
    assert_eq!(sub.state.counter("completed_total"), 1, "counted once");
    assert!(!sub.queue.renew(&stale_lease), "stale lease cannot renew");
    assert!(sub.queue.is_empty());
}

#[test]
fn renewal_defers_failure_detection_until_worker_actually_dies() {
    // A healthy-then-dead worker: renewals hold the task invisible
    // past several lease periods; death (no more renewals) surrenders
    // it one visibility timeout later — that *is* failure detection.
    let (sub, clock) = substrate("sharded:2");
    sub.queue.send("t", 0);
    let (_, lease) = sub.queue.receive().unwrap();
    for _ in 0..5 {
        clock.advance(LEASE / 2);
        assert!(sub.queue.renew(&lease), "healthy worker keeps renewing");
        assert!(sub.queue.receive().is_none(), "invisible while renewed");
    }
    // Death: renewals stop. Visible again exactly one lease later.
    clock.advance(LEASE + Duration::from_secs(1));
    assert_eq!(sub.queue.receive().unwrap().0, "t");
    assert_eq!(sub.queue.delivery_count("t"), 2);
}

#[test]
fn chaos_dropped_delivery_recovers_through_same_lease_path() {
    // A chaos-dropped delivery is indistinguishable from a worker that
    // died immediately after receive: lease taken, no effects, expiry
    // redelivers.
    let (sub, clock) = substrate("strict+chaos(drop=1.0,seed=5)");
    sub.queue.send("t", 0);
    assert!(sub.queue.receive().is_none(), "drop=1 swallows the delivery");
    assert_eq!(sub.queue.len(), 1, "message not lost");
    assert_eq!(sub.queue.visible_len(), 0, "…but leased");
    clock.advance(LEASE + Duration::from_secs(1));
    assert_eq!(sub.queue.visible_len(), 1, "expiry resurfaces it");
    assert_eq!(sub.queue.delivery_count("t"), 1);
}

#[test]
fn transient_blob_faults_do_not_corrupt_accounting() {
    // Failed (injected) puts/gets must not register bytes or objects:
    // the decorator rejects before the inner store sees the op.
    let (sub, _) = substrate("strict+chaos(err=0.5,seed=12)");
    let mut failures = 0usize;
    let mut successes = 0usize;
    for i in 0..64 {
        match sub.blob.put(0, &format!("K[{i}]"), Matrix::eye(1)) {
            Ok(()) => successes += 1,
            Err(e) => {
                assert!(is_transient(&e), "injected faults carry the marker");
                failures += 1;
            }
        }
    }
    assert!(failures > 0 && successes > 0, "err=0.5 must split outcomes");
    assert_eq!(sub.blob.len(), successes, "only successful puts stored");
    assert_eq!(sub.blob.stats().put_ops, successes as u64);
    assert_eq!(sub.blob.stats().bytes_written, successes as u64 * 8);
}
