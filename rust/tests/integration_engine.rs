//! Integration tests: the full engine (queue + state store + object
//! store + workers + provisioner) under fault injection, stragglers,
//! runtime limits, pipelining, and autoscaling — the §4.1/§4.2
//! machinery end-to-end on real numerics.

use numpywren::config::{EngineConfig, FailureSpec, ScalingMode};
use numpywren::drivers;
use numpywren::engine::Engine;
use numpywren::linalg::matrix::Matrix;
use numpywren::storage::BlobStore as _;
use numpywren::util::prng::Rng;
use std::time::Duration;

fn spd(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::rand_spd(n, &mut rng)
}

fn base_cfg() -> EngineConfig {
    EngineConfig {
        job_timeout: Duration::from_secs(120),
        ..EngineConfig::default()
    }
}

#[test]
fn fixed_pool_cholesky_correct() {
    let a = spd(32, 1);
    let mut cfg = base_cfg();
    cfg.scaling = ScalingMode::Fixed(6);
    let out = drivers::cholesky(&Engine::new(cfg), &a, 8).unwrap();
    assert!(out.result.matmul_nt(&out.result).max_abs_diff(&a) < 1e-8);
    let r = &out.run.report;
    assert_eq!(r.completed, r.total_tasks);
    assert!(r.store.bytes_read > 0 && r.store.bytes_written > 0);
    assert!(r.total_flops > 0);
    assert!(r.error.is_none());
}

#[test]
fn autoscaled_cholesky_scales_up_and_down() {
    let a = spd(32, 2);
    let mut cfg = base_cfg();
    cfg.scaling = ScalingMode::Auto {
        sf: 1.0,
        max_workers: 8,
    };
    cfg.idle_timeout = Duration::from_millis(50);
    cfg.provision_period = Duration::from_millis(10);
    let out = drivers::cholesky(&Engine::new(cfg), &a, 8).unwrap();
    assert!(out.result.matmul_nt(&out.result).max_abs_diff(&a) < 1e-8);
    let r = &out.run.report;
    assert!(r.workers_spawned >= 1);
    // Auto-scaled workers exit on idle or job completion.
    assert_eq!(r.completed, r.total_tasks);
}

#[test]
fn failure_injection_recovers() {
    // Kill 60% of the pool mid-run (Figure 9b at miniature scale):
    // leases expire, tasks redeliver, the provisioner replenishes, the
    // job completes and the numbers are right.
    let a = spd(40, 3);
    let mut cfg = base_cfg();
    cfg.scaling = ScalingMode::Auto {
        sf: 1.0,
        max_workers: 6,
    };
    cfg.lease = Duration::from_millis(100);
    cfg.idle_timeout = Duration::from_millis(80);
    cfg.provision_period = Duration::from_millis(10);
    cfg.store_latency = Duration::from_micros(300); // slow things down
    cfg.failure = Some(FailureSpec {
        at: Duration::from_millis(60),
        fraction: 0.6,
    });
    let out = drivers::cholesky(&Engine::new(cfg), &a, 8).unwrap();
    assert!(out.result.matmul_nt(&out.result).max_abs_diff(&a) < 1e-8);
    let r = &out.run.report;
    assert_eq!(r.completed, r.total_tasks);
    assert!(r.error.is_none());
}

#[test]
fn straggler_duplicate_execution_is_safe() {
    // A lease much shorter than the injected store latency forces
    // redeliveries while the original holder still works: tasks execute
    // more than once. Idempotence (SSA writes + CAS completion + edge-
    // guarded decrements) must keep the result exact.
    let a = spd(24, 4);
    let mut cfg = base_cfg();
    cfg.scaling = ScalingMode::Fixed(6);
    cfg.lease = Duration::from_millis(20);
    cfg.store_latency = Duration::from_millis(8);
    let out = drivers::cholesky(&Engine::new(cfg), &a, 8).unwrap();
    assert!(out.result.matmul_nt(&out.result).max_abs_diff(&a) < 1e-8);
    let r = &out.run.report;
    // completed counts CAS winners — exactly the task count even if
    // more executions happened.
    assert_eq!(r.completed, r.total_tasks);
    // Task records may exceed total (duplicates recorded).
    assert!(r.tasks.len() as u64 >= r.total_tasks);
}

#[test]
fn runtime_limit_recycles_workers() {
    // Lambda-style: invocations die every 150 ms (with a cold start on
    // re-entry) — the job must still complete.
    let a = spd(24, 5);
    let mut cfg = base_cfg();
    cfg.scaling = ScalingMode::Fixed(4);
    cfg.runtime_limit = Duration::from_millis(150);
    cfg.cold_start = Duration::from_millis(10);
    cfg.store_latency = Duration::from_micros(200);
    let out = drivers::cholesky(&Engine::new(cfg), &a, 8).unwrap();
    assert!(out.result.matmul_nt(&out.result).max_abs_diff(&a) < 1e-8);
}

#[test]
fn pipelining_correct_and_overlaps() {
    let a = spd(40, 6);
    let mut cfg = base_cfg();
    cfg.scaling = ScalingMode::Fixed(3);
    cfg.pipeline_width = 3;
    cfg.store_latency = Duration::from_micros(500);
    let out = drivers::cholesky(&Engine::new(cfg), &a, 8).unwrap();
    assert!(out.result.matmul_nt(&out.result).max_abs_diff(&a) < 1e-8);
}

#[test]
fn gemm_under_faults() {
    let mut rng = Rng::new(7);
    let a = Matrix::randn(24, 24, &mut rng);
    let b = Matrix::randn(24, 24, &mut rng);
    let mut cfg = base_cfg();
    cfg.scaling = ScalingMode::Auto {
        sf: 0.5,
        max_workers: 5,
    };
    cfg.lease = Duration::from_millis(100);
    cfg.idle_timeout = Duration::from_millis(60);
    cfg.provision_period = Duration::from_millis(10);
    cfg.failure = Some(FailureSpec {
        at: Duration::from_millis(40),
        fraction: 0.5,
    });
    cfg.store_latency = Duration::from_micros(200);
    let out = drivers::gemm(&Engine::new(cfg), &a, &b, 8).unwrap();
    assert!(out.result.max_abs_diff(&a.matmul(&b)) < 1e-9);
}

#[test]
fn tsqr_autoscaled() {
    let mut rng = Rng::new(8);
    let a = Matrix::randn(64, 8, &mut rng);
    let mut cfg = base_cfg();
    cfg.scaling = ScalingMode::Auto {
        sf: 1.0,
        max_workers: 6,
    };
    cfg.idle_timeout = Duration::from_millis(60);
    cfg.provision_period = Duration::from_millis(10);
    let out = drivers::tsqr(&Engine::new(cfg), &a, 8).unwrap();
    let r = &out.result;
    assert!(r.matmul_tn(r).max_abs_diff(&a.matmul_tn(&a)) < 1e-8);
}

#[test]
fn qr_with_pipelining() {
    let mut rng = Rng::new(9);
    let a = Matrix::randn(24, 24, &mut rng);
    let mut cfg = base_cfg();
    cfg.scaling = ScalingMode::Fixed(4);
    cfg.pipeline_width = 2;
    let out = drivers::qr(&Engine::new(cfg), &a, 8).unwrap();
    let r = &out.result;
    assert!(r.matmul_tn(r).max_abs_diff(&a.matmul_tn(&a)) < 1e-8);
}

#[test]
fn non_spd_input_aborts_with_error() {
    // chol of an indefinite matrix must fail the job cleanly, not hang.
    let mut a = Matrix::eye(16);
    a[(0, 0)] = -5.0;
    let mut cfg = base_cfg();
    cfg.scaling = ScalingMode::Fixed(2);
    cfg.job_timeout = Duration::from_secs(20);
    let msg = match drivers::cholesky(&Engine::new(cfg), &a, 8) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("indefinite matrix must fail"),
    };
    assert!(
        msg.contains("positive definite") || msg.contains("cholesky"),
        "unexpected error: {msg}"
    );
}

#[test]
fn metrics_profile_nonempty() {
    let a = spd(32, 10);
    let mut cfg = base_cfg();
    cfg.scaling = ScalingMode::Fixed(4);
    cfg.sample_period = Duration::from_millis(2);
    cfg.store_latency = Duration::from_micros(300);
    let out = drivers::cholesky(&Engine::new(cfg), &a, 8).unwrap();
    let r = &out.run.report;
    assert!(r.samples.len() >= 2, "sampler must have run");
    assert!(r.core_secs_active >= 0.0);
    assert!(r.core_secs_billed > 0.0);
    // Per-worker byte accounting (Figure 7 mechanics).
    let workers = out.run.store.known_workers();
    assert!(!workers.is_empty());
}

#[test]
fn pjrt_full_stack_cholesky() {
    // The production path end-to-end: serverless engine + AOT-compiled
    // JAX/Pallas kernels via PJRT (f32), verified against the input.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let kernels =
        std::sync::Arc::new(numpywren::runtime::PjrtKernels::new(&dir, 2).unwrap());
    let a = spd(128, 11);
    let mut cfg = base_cfg();
    cfg.scaling = ScalingMode::Fixed(4);
    let engine = Engine::with_kernels(cfg, kernels.clone());
    let out = drivers::cholesky(&engine, &a, 32).unwrap();
    let l = &out.result;
    let rel = l.matmul_nt(l).max_abs_diff(&a) / a.fro_norm();
    assert!(rel < 1e-4, "relative reconstruction error {rel}");
    let (pjrt, _native) = kernels.call_counts();
    assert!(pjrt > 0, "PJRT path must actually serve kernels");
}

#[test]
fn pjrt_full_stack_gemm() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        return;
    }
    let kernels =
        std::sync::Arc::new(numpywren::runtime::PjrtKernels::new(&dir, 2).unwrap());
    let mut rng = Rng::new(12);
    let a = Matrix::randn(96, 96, &mut rng);
    let b = Matrix::randn(96, 96, &mut rng);
    let mut cfg = base_cfg();
    cfg.scaling = ScalingMode::Fixed(4);
    let engine = Engine::with_kernels(cfg, kernels.clone());
    let out = drivers::gemm(&engine, &a, &b, 32).unwrap();
    let rel = out.result.max_abs_diff(&a.matmul(&b)) / a.fro_norm();
    assert!(rel < 1e-4, "relative error {rel}");
    assert!(kernels.call_counts().0 > 0);
}
