//! Blocked-kernel equivalence and determinism contract.
//!
//! Two families of guarantees pin the blocked packed GEMM path
//! (`linalg::gemm`) and everything routed through it:
//!
//! 1. **Equivalence** — the blocked kernels match the naive sub-cutoff
//!    oracle (the original loops, kept verbatim) tolerance-bounded,
//!    across rectangular, odd, and degenerate shapes, and the blocked
//!    triangular solves match the unblocked reference recurrence.
//! 2. **Determinism** — same inputs produce bit-identical outputs
//!    across repeated calls, across scratch reuse, and across worker
//!    threads. The SSA bit-exact duplicate machinery (speculative
//!    re-execution, crash-restart recovery) compares tiles with
//!    `max_abs_diff == 0.0`; this suite is the contract those tests
//!    rely on.

use numpywren::kernels::{KernelExecutor, KernelScratch, NativeKernels};
use numpywren::linalg::factor;
use numpywren::linalg::gemm::{self, Scratch, Trans};
use numpywren::linalg::matrix::Matrix;
use numpywren::util::prng::Rng;
use std::sync::Arc;

fn rand(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::randn(rows, cols, &mut rng)
}

/// Well-conditioned lower-triangular factor (from an SPD tile).
fn lower(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let a = Matrix::rand_spd(n, &mut rng);
    factor::cholesky(&a).unwrap()
}

// ---------------------------------------------------------------
// Equivalence: blocked vs the naive oracle
// ---------------------------------------------------------------

#[test]
fn blocked_gemm_matches_oracle_across_shapes() {
    // (m, n, k) grid: sub-tile, register-tile edges, cache-block
    // straddles, skinny and tall extremes.
    let shapes = [
        (1, 1, 1),
        (3, 5, 7),
        (4, 8, 16),
        (63, 65, 64),
        (64, 64, 64),
        (65, 63, 130),
        (100, 1, 50),
        (1, 100, 50),
        (200, 9, 257),
        (129, 140, 300),
    ];
    let mut s = Scratch::new();
    for (i, (m, n, k)) in shapes.into_iter().enumerate() {
        let seed = 100 + i as u64;
        let a_nn = rand(m, k, seed);
        let b_nn = rand(k, n, seed + 50);
        let cases = [
            (a_nn.clone(), Trans::N, b_nn.clone(), Trans::N),
            (a_nn.clone(), Trans::N, b_nn.transpose(), Trans::T),
            (a_nn.transpose(), Trans::T, b_nn.clone(), Trans::N),
            (a_nn.transpose(), Trans::T, b_nn.transpose(), Trans::T),
        ];
        for (a, ta, b, tb) in cases {
            let blocked = gemm::product_blocked(&a, ta, &b, tb, &mut s);
            let oracle = gemm::product_naive(&a, ta, &b, tb);
            assert_eq!(blocked.shape(), (m, n));
            let diff = blocked.max_abs_diff(&oracle);
            assert!(diff < 1e-9, "({m},{n},{k}) {ta:?}{tb:?}: diff {diff}");
        }
    }
}

#[test]
fn matmul_wrappers_dispatch_deterministically() {
    // Below the cutoff the wrappers must run the ORIGINAL loops
    // bit-identically (pre-existing small-tile numerics are frozen);
    // above it they must equal the forced-blocked path bit-identically
    // (dispatch is a pure function of dims — never data).
    let small_a = rand(40, 63, 1);
    let small_b = rand(63, 50, 2);
    assert_eq!(
        small_a.matmul(&small_b).data(),
        small_a.matmul_naive(&small_b).data()
    );
    assert_eq!(
        small_a.matmul_nt(&small_a).data(),
        small_a.matmul_nt_naive(&small_a).data()
    );
    assert_eq!(
        small_a.matmul_tn(&small_a).data(),
        small_a.matmul_tn_naive(&small_a).data()
    );

    let big_a = rand(96, 80, 3);
    let big_b = rand(80, 70, 4);
    let mut s = Scratch::new();
    assert_eq!(
        big_a.matmul(&big_b).data(),
        gemm::product_blocked(&big_a, Trans::N, &big_b, Trans::N, &mut s).data()
    );
    assert_eq!(
        big_a.matmul_nt(&big_a).data(),
        gemm::product_blocked(&big_a, Trans::N, &big_a, Trans::T, &mut s).data()
    );
    assert_eq!(
        big_a.matmul_tn(&big_a).data(),
        gemm::product_blocked(&big_a, Trans::T, &big_a, Trans::N, &mut s).data()
    );
}

#[test]
fn degenerate_dims_are_safe_everywhere() {
    let mut s = Scratch::new();
    for (m, n, k) in [(0, 5, 3), (5, 0, 3), (5, 3, 0), (0, 0, 0)] {
        let a = rand(m, k, 7);
        let b = rand(k, n, 8);
        let blocked = gemm::product_blocked(&a, Trans::N, &b, Trans::N, &mut s);
        let oracle = gemm::product_naive(&a, Trans::N, &b, Trans::N);
        assert_eq!(blocked.shape(), (m, n));
        assert_eq!(blocked.data(), oracle.data());
        // k = 0 must yield an exact zero product, not garbage.
        if k == 0 {
            assert_eq!(blocked.fro_norm(), 0.0);
        }
    }
    // Degenerate transpose round-trips.
    let e = Matrix::zeros(0, 7);
    assert_eq!(e.transpose().shape(), (7, 0));
    assert_eq!(e.transpose().transpose().shape(), (0, 7));
}

#[test]
fn transpose_blocked_matches_elementwise() {
    // Odd shape straddling several 32-tiles in both directions.
    let a = rand(129, 257, 9);
    let t = a.transpose();
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            assert_eq!(t[(j, i)], a[(i, j)]);
        }
    }
}

// ---------------------------------------------------------------
// Blocked triangular solves vs the unblocked reference recurrence
// ---------------------------------------------------------------

/// The original (pre-blocking) trsm_right_lt recurrence, verbatim.
fn ref_trsm_right_lt(l: &Matrix, a: &Matrix) -> Matrix {
    let n = l.rows();
    let m = a.rows();
    let mut x = a.clone();
    for j in 0..n {
        let d = l[(j, j)];
        for i in 0..m {
            let mut s = x[(i, j)];
            for k in 0..j {
                s -= x[(i, k)] * l[(j, k)];
            }
            x[(i, j)] = s / d;
        }
    }
    x
}

fn ref_trsm_left_lower(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    let w = b.cols();
    let mut x = b.clone();
    for i in 0..n {
        let d = l[(i, i)];
        for j in 0..w {
            let mut s = x[(i, j)];
            for k in 0..i {
                s -= l[(i, k)] * x[(k, j)];
            }
            x[(i, j)] = s / d;
        }
    }
    x
}

fn ref_trsm_left_upper(u: &Matrix, b: &Matrix) -> Matrix {
    let n = u.rows();
    let w = b.cols();
    let mut x = b.clone();
    for i in (0..n).rev() {
        let d = u[(i, i)];
        for j in 0..w {
            let mut s = x[(i, j)];
            for k in (i + 1)..n {
                s -= u[(i, k)] * x[(k, j)];
            }
            x[(i, j)] = s / d;
        }
    }
    x
}

fn ref_trsm_right_upper(u: &Matrix, b: &Matrix) -> Matrix {
    let n = u.rows();
    let m = b.rows();
    let mut x = b.clone();
    for j in 0..n {
        let d = u[(j, j)];
        for i in 0..m {
            let mut s = x[(i, j)];
            for k in 0..j {
                s -= x[(i, k)] * u[(k, j)];
            }
            x[(i, j)] = s / d;
        }
    }
    x
}

#[test]
fn blocked_trsm_family_matches_reference() {
    // n = 150 forces multiple 64-wide panels (multi-panel + trailing
    // GEMM); n = 40 stays single-panel and must be bit-identical.
    for (n, m, tol) in [(150, 97, 1e-8), (40, 23, 0.0_f64)] {
        let l = lower(n, 1000 + n as u64);
        let u = l.transpose();
        let rhs_right = rand(m, n, 2000 + n as u64);
        let rhs_left = rand(n, m, 3000 + n as u64);

        let cases: [(Matrix, Matrix); 4] = [
            (
                factor::trsm_right_lt(&l, &rhs_right).unwrap(),
                ref_trsm_right_lt(&l, &rhs_right),
            ),
            (
                factor::trsm_left_lower(&l, &rhs_left).unwrap(),
                ref_trsm_left_lower(&l, &rhs_left),
            ),
            (
                factor::trsm_left_upper(&u, &rhs_left).unwrap(),
                ref_trsm_left_upper(&u, &rhs_left),
            ),
            (
                factor::trsm_right_upper(&u, &rhs_right).unwrap(),
                ref_trsm_right_upper(&u, &rhs_right),
            ),
        ];
        for (i, (got, want)) in cases.iter().enumerate() {
            let diff = got.max_abs_diff(want);
            assert!(diff <= tol, "trsm case {i} at n={n}: diff {diff} > {tol}");
        }
        // Residual check on the multi-panel size: the blocked solve
        // actually solves the system, not just matches a recurrence.
        let x = factor::trsm_right_lt(&l, &rhs_right).unwrap();
        assert!(x.matmul_nt(&l).max_abs_diff(&rhs_right) < 1e-8);
    }
}

#[test]
fn trsm_still_rejects_singular_factors() {
    let mut l = lower(100, 55);
    l[(70, 70)] = 0.0; // singular pivot inside the second panel
    let b = rand(10, 100, 56);
    let err = factor::trsm_right_lt(&l, &b).unwrap_err().to_string();
    assert!(err.contains("singular"), "{err}");
    assert!(err.contains("70"), "pivot index preserved: {err}");
}

// ---------------------------------------------------------------
// Determinism: repeated calls, scratch reuse, worker threads
// ---------------------------------------------------------------

#[test]
fn gemm_bit_identical_across_calls_scratch_and_threads() {
    let a = Arc::new(rand(300, 220, 11));
    let b = Arc::new(rand(220, 180, 12));
    let reference = a.matmul(&b);

    // Repeated calls + scratch-state perturbation in between.
    let mut s = Scratch::new();
    let r1 = gemm::product_blocked(&a, Trans::N, &b, Trans::N, &mut s);
    let _ = gemm::product_blocked(&b, Trans::T, &a, Trans::T, &mut s);
    let r2 = gemm::product_blocked(&a, Trans::N, &b, Trans::N, &mut s);
    assert_eq!(r1.data(), reference.data());
    assert_eq!(r2.data(), reference.data());

    // Worker threads: each with its own scratch, repeated calls.
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let (a, b, want) = (a.clone(), b.clone(), reference.clone());
            std::thread::spawn(move || {
                let mut s = Scratch::new();
                for _ in 0..3 {
                    let got = gemm::product_blocked(&a, Trans::N, &b, Trans::N, &mut s);
                    assert_eq!(got.data(), want.data(), "thread diverged");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn native_kernels_execute_paths_bit_identical() {
    // `execute` (thread-local scratch) and `execute_with_scratch`
    // (explicit worker scratch, fresh or reused) must agree bitwise
    // for every GEMM-routed kernel — the worker compute stage uses the
    // scratch path, tests and tools the plain one.
    let nk = NativeKernels;
    let n = 150;
    let l = Arc::new(lower(n, 21));
    let s_tile = Arc::new(rand(n, n, 22));
    let a_tile = Arc::new(rand(n, n, 23));
    let b_tile = Arc::new(rand(n, n, 24));
    let u = Arc::new(l.transpose());
    let (q, _r) = factor::qr_full(&rand(n, n / 2, 25)).unwrap();
    let q = Arc::new(q);
    let spd = Arc::new({
        let mut rng = Rng::new(26);
        Matrix::rand_spd(n, &mut rng)
    });

    let calls: Vec<(&str, Vec<Arc<Matrix>>)> = vec![
        ("trsm", vec![l.clone(), a_tile.clone()]),
        ("syrk", vec![s_tile.clone(), a_tile.clone(), b_tile.clone()]),
        ("gemm_kernel", vec![a_tile.clone(), b_tile.clone()]),
        (
            "gemm_accum",
            vec![s_tile.clone(), a_tile.clone(), b_tile.clone()],
        ),
        (
            "gemm_sub",
            vec![s_tile.clone(), a_tile.clone(), b_tile.clone()],
        ),
        ("trsm_lower", vec![l.clone(), a_tile.clone()]),
        ("trsm_upper", vec![u.clone(), a_tile.clone()]),
        ("qr_apply1", vec![a_tile.clone(), q.clone()]),
        ("lq_apply1", vec![a_tile.clone(), q.clone()]),
        ("chol", vec![spd.clone()]),
    ];

    let mut reused = KernelScratch::default();
    for (name, inputs) in &calls {
        let plain = nk.execute(name, inputs, &[]).unwrap();
        let fresh = nk
            .execute_with_scratch(name, inputs, &[], &mut KernelScratch::default())
            .unwrap();
        let warm = nk
            .execute_with_scratch(name, inputs, &[], &mut reused)
            .unwrap();
        let again = nk.execute(name, inputs, &[]).unwrap();
        assert_eq!(plain.len(), fresh.len());
        for i in 0..plain.len() {
            assert_eq!(plain[i].data(), fresh[i].data(), "{name}[{i}] fresh");
            assert_eq!(plain[i].data(), warm[i].data(), "{name}[{i}] warm");
            assert_eq!(plain[i].data(), again[i].data(), "{name}[{i}] repeat");
        }
    }
}

#[test]
fn factor_ws_variants_match_plain() {
    // The `_ws` scratch-handle variants are the same computation as
    // the thread-local-wrapped plain names — bitwise.
    let n = 140;
    let l = lower(n, 31);
    let s_tile = rand(n, n, 32);
    let a = rand(n, n, 33);
    let b = rand(n, n, 34);
    let mut sc = Scratch::new();

    assert_eq!(
        factor::syrk_update(&s_tile, &a, &b).unwrap().data(),
        factor::syrk_update_ws(&s_tile, &a, &b, &mut sc).unwrap().data()
    );
    assert_eq!(
        factor::gemm(&a, &b).unwrap().data(),
        factor::gemm_ws(&a, &b, &mut sc).unwrap().data()
    );
    assert_eq!(
        factor::gemm_accum(&s_tile, &a, &b).unwrap().data(),
        factor::gemm_accum_ws(&s_tile, &a, &b, &mut sc).unwrap().data()
    );
    assert_eq!(
        factor::trsm_right_lt(&l, &a).unwrap().data(),
        factor::trsm_right_lt_ws(&l, &a, &mut sc).unwrap().data()
    );
    assert_eq!(
        factor::trsm_left_lower(&l, &a).unwrap().data(),
        factor::trsm_left_lower_ws(&l, &a, &mut sc).unwrap().data()
    );
    // Scratch footprint is bounded and reused, not re-grown.
    let high_water = sc.footprint_bytes();
    let _ = factor::gemm_ws(&a, &b, &mut sc).unwrap();
    assert_eq!(sc.footprint_bytes(), high_water);
}
