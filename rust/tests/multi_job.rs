//! Multi-tenant isolation tests: N concurrent LAmbdaPACK jobs on ONE
//! shared substrate and ONE shared, job-agnostic worker fleet.
//!
//! What must hold (the tentpole's acceptance bar):
//! * every job's numerics are exact — cross-job key collisions or
//!   misrouted messages would corrupt them;
//! * per-job completed-task counts are exact (the namespaced
//!   completed counter counts only CAS winners);
//! * no cross-job key collisions in the shared blob store — checked
//!   by exact key-count accounting (each job's distinct keys = its
//!   seed tiles + its SSA task writes; any collision shrinks the sum);
//! * the composite (class, line, FIFO) priority lets a small urgent
//!   job finish while a large batch job is still running;
//! * cancel drains a job and frees the fleet for the next one.

use numpywren::config::{EngineConfig, RetentionPolicy, ScalingMode};
use numpywren::drivers::{
    collect_cholesky, collect_gemm, stage_cholesky, stage_gemm, stage_gemm_after_cholesky,
    stage_gemm_after_gemm,
};
use numpywren::jobs::{JobId, JobManager, JobSpec, JobStatus};
use numpywren::lambdapack::programs;
use numpywren::linalg::{factor, matrix::Matrix};
use numpywren::storage::{BlobStore as _, KvState as _};
use numpywren::util::prng::Rng;
use std::time::{Duration, Instant};

fn base_cfg(workers: usize) -> EngineConfig {
    EngineConfig {
        scaling: ScalingMode::Fixed(workers),
        job_timeout: Duration::from_secs(120),
        ..EngineConfig::default()
    }
}

/// Submit a Cholesky job; returns (id, grid_n, seed_tile_count).
fn submit_cholesky(
    mgr: &JobManager,
    a: &Matrix,
    block: usize,
    class: i64,
) -> (JobId, usize, usize) {
    let (env, inputs, grid) = stage_cholesky(a, block).unwrap();
    let seeds = inputs.len();
    let job = mgr
        .submit(JobSpec::new(programs::cholesky_spec().program, env, inputs).with_class(class))
        .unwrap();
    (job, grid, seeds)
}

/// Submit a GEMM job; returns (id, grid_n, seed_tile_count).
fn submit_gemm(
    mgr: &JobManager,
    a: &Matrix,
    b: &Matrix,
    block: usize,
    class: i64,
) -> (JobId, usize, usize) {
    let (env, inputs, grid) = stage_gemm(a, b, block).unwrap();
    let seeds = inputs.len();
    let job = mgr
        .submit(JobSpec::new(programs::gemm_spec().program, env, inputs).with_class(class))
        .unwrap();
    (job, grid, seeds)
}

#[test]
fn four_concurrent_jobs_isolated_and_exact() {
    // Runs on the default substrate, so the CI matrix
    // (NUMPYWREN_SUBSTRATE) exercises multi-tenancy on every backend
    // family, chaos-wrapped included.
    let mgr = JobManager::new(base_cfg(6));
    let mut rng = Rng::new(0x30B5);
    let a1 = Matrix::rand_spd(24, &mut rng);
    let a2 = Matrix::rand_spd(32, &mut rng);
    let ga = Matrix::randn(18, 18, &mut rng);
    let gb = Matrix::randn(18, 18, &mut rng);
    let gc = Matrix::randn(12, 12, &mut rng);
    let gd = Matrix::randn(12, 12, &mut rng);

    // Interleave submissions: 2 Cholesky + 2 GEMM, all in flight at
    // once on one fleet.
    let (c1, c1_grid, c1_seeds) = submit_cholesky(&mgr, &a1, 8, 0);
    let (g1, g1_grid, g1_seeds) = submit_gemm(&mgr, &ga, &gb, 6, 0);
    let (c2, c2_grid, c2_seeds) = submit_cholesky(&mgr, &a2, 8, 0);
    let (g2, g2_grid, g2_seeds) = submit_gemm(&mgr, &gc, &gd, 6, 0);
    assert_eq!(mgr.active_jobs(), 4);

    // Await all four; every report must be exact and per-job.
    let rc1 = mgr.wait(c1).unwrap();
    let rg1 = mgr.wait(g1).unwrap();
    let rc2 = mgr.wait(c2).unwrap();
    let rg2 = mgr.wait(g2).unwrap();
    for (r, label) in [
        (&rc1, "cholesky"),
        (&rg1, "gemm"),
        (&rc2, "cholesky"),
        (&rg2, "gemm"),
    ] {
        assert_eq!(r.completed, r.total_tasks, "[{}] exact task count", r.job);
        assert!(r.error.is_none(), "[{}] {:?}", r.job, r.error);
        assert!(!r.canceled);
        assert_eq!(r.label, label);
        assert!(!r.samples.is_empty(), "[{}] final sample recorded", r.job);
        assert!(r.tasks.len() as u64 >= r.total_tasks, "[{}]", r.job);
    }
    assert_eq!(mgr.status(c1), JobStatus::Succeeded);

    // Exact numerics per job, fetched through the namespaced API.
    let f1 = |m: &str, idx: &[i64]| mgr.tile(c1, m, idx);
    let l1 = collect_cholesky(&f1, a1.rows(), 8, c1_grid).unwrap();
    assert!(l1.matmul_nt(&l1).max_abs_diff(&a1) < 1e-8, "job c1 LLᵀ ≠ A");
    let f2 = |m: &str, idx: &[i64]| mgr.tile(c2, m, idx);
    let l2 = collect_cholesky(&f2, a2.rows(), 8, c2_grid).unwrap();
    assert!(l2.matmul_nt(&l2).max_abs_diff(&a2) < 1e-8, "job c2 LLᵀ ≠ A");
    let f3 = |m: &str, idx: &[i64]| mgr.tile(g1, m, idx);
    let p1 = collect_gemm(&f3, 18, 18, 6, g1_grid).unwrap();
    assert!(p1.max_abs_diff(&ga.matmul(&gb)) < 1e-9, "job g1 C ≠ AB");
    let f4 = |m: &str, idx: &[i64]| mgr.tile(g2, m, idx);
    let p2 = collect_gemm(&f4, 12, 12, 6, g2_grid).unwrap();
    assert!(p2.max_abs_diff(&gc.matmul(&gd)) < 1e-9, "job g2 C ≠ AB");

    // No cross-job key collisions: every job contributes exactly its
    // seed tiles plus one SSA write per task; a single collision
    // anywhere would shrink the shared store's distinct-key count.
    let expected: u64 = [
        (c1_seeds as u64, rc1.total_tasks),
        (g1_seeds as u64, rg1.total_tasks),
        (c2_seeds as u64, rc2.total_tasks),
        (g2_seeds as u64, rg2.total_tasks),
    ]
    .iter()
    .map(|(seeds, tasks)| seeds + tasks)
    .sum();
    assert_eq!(mgr.store().len() as u64, expected, "cross-job key collision");

    let fleet = mgr.shutdown();
    assert_eq!(fleet.workers_spawned, 6, "one shared fixed fleet");
    assert!(fleet.core_secs_billed > 0.0);
    assert!(fleet.store.bytes_written > 0);
}

#[test]
fn concurrent_jobs_exact_under_chaos_faults() {
    // The chaos leg: transient blob faults + shaped latency on the
    // shared substrate; both jobs must still be numerically exact with
    // exact per-job completed counts.
    let mut cfg = base_cfg(5);
    cfg.set("substrate", "sharded:4+chaos(err=0.05,lat=fixed:50us,seed=31)")
        .unwrap();
    let mgr = JobManager::new(cfg);
    let mut rng = Rng::new(0xC4A5);
    let a = Matrix::rand_spd(24, &mut rng);
    let ga = Matrix::randn(18, 18, &mut rng);
    let gb = Matrix::randn(18, 18, &mut rng);
    let (cj, c_grid, _) = submit_cholesky(&mgr, &a, 8, 0);
    let (gj, g_grid, _) = submit_gemm(&mgr, &ga, &gb, 6, 0);
    let rc = mgr.wait(cj).unwrap();
    let rg = mgr.wait(gj).unwrap();
    assert_eq!(rc.completed, rc.total_tasks);
    assert_eq!(rg.completed, rg.total_tasks);
    assert!(rc.error.is_none() && rg.error.is_none());
    let fc = |m: &str, idx: &[i64]| mgr.tile(cj, m, idx);
    let l = collect_cholesky(&fc, a.rows(), 8, c_grid).unwrap();
    assert!(l.matmul_nt(&l).max_abs_diff(&a) < 1e-8);
    let fg = |m: &str, idx: &[i64]| mgr.tile(gj, m, idx);
    let c = collect_gemm(&fg, 18, 18, 6, g_grid).unwrap();
    assert!(c.max_abs_diff(&ga.matmul(&gb)) < 1e-9);
}

#[test]
fn urgent_small_job_finishes_while_batch_job_runs() {
    // Fair-share / composite priority: a large class-0 batch job is
    // mid-flight on a slow 2-worker fleet when a small class-1 job
    // arrives; the urgent job's tasks jump the shared queue, so it
    // finishes while the batch job is still running. Pinned to a
    // chaos-free substrate: an env-injected `drop=` clause would put a
    // ~500 ms lease-recovery stall on the timing this test asserts.
    let mut cfg = base_cfg(2);
    cfg.set("substrate", "sharded:8").unwrap();
    cfg.store_latency = Duration::from_micros(200);
    let mgr = JobManager::new(cfg);
    let mut rng = Rng::new(0xFA1);
    let big = Matrix::rand_spd(48, &mut rng); // grid 12 → hundreds of tasks
    let small_a = Matrix::randn(8, 8, &mut rng);
    let small_b = Matrix::randn(8, 8, &mut rng);
    let (big_job, _, _) = submit_cholesky(&mgr, &big, 4, 0);
    let (small_job, small_grid, _) = submit_gemm(&mgr, &small_a, &small_b, 4, 1);
    let small_report = mgr.wait(small_job).unwrap();
    assert_eq!(small_report.completed, small_report.total_tasks);
    assert!(
        matches!(mgr.status(big_job), JobStatus::Running { .. }),
        "urgent job done while the batch job still runs"
    );
    let fetch = |m: &str, idx: &[i64]| mgr.tile(small_job, m, idx);
    let c = collect_gemm(&fetch, 8, 8, 4, small_grid).unwrap();
    assert!(c.max_abs_diff(&small_a.matmul(&small_b)) < 1e-9);
    let big_report = mgr.wait(big_job).unwrap();
    assert_eq!(big_report.completed, big_report.total_tasks);
    assert!(
        small_report.wall_secs < big_report.wall_secs,
        "small urgent job must finish first ({:.3}s vs {:.3}s)",
        small_report.wall_secs,
        big_report.wall_secs
    );
}

#[test]
fn cancel_drains_job_and_frees_the_fleet() {
    let mut cfg = base_cfg(2);
    cfg.store_latency = Duration::from_micros(200);
    let mgr = JobManager::new(cfg);
    let mut rng = Rng::new(0xDEAD);
    let big = Matrix::rand_spd(48, &mut rng);
    let (big_job, _, _) = submit_cholesky(&mgr, &big, 4, 0);
    assert!(mgr.cancel(big_job));
    let r = mgr.wait(big_job).unwrap();
    assert!(r.canceled);
    assert!(r.error.is_some());
    assert_eq!(mgr.status(big_job), JobStatus::Canceled);
    // Canceling again is a no-op (job already sealed).
    assert!(!mgr.cancel(big_job));
    // The fleet keeps serving: a fresh job completes exactly.
    let a = Matrix::rand_spd(16, &mut rng);
    let (job, grid, _) = submit_cholesky(&mgr, &a, 8, 0);
    let r = mgr.wait(job).unwrap();
    assert_eq!(r.completed, r.total_tasks);
    let fetch = |m: &str, idx: &[i64]| mgr.tile(job, m, idx);
    let l = collect_cholesky(&fetch, a.rows(), 8, grid).unwrap();
    assert!(l.matmul_nt(&l).max_abs_diff(&a) < 1e-8);
}

/// GC is asynchronous (deferred past the last in-flight pipeline task
/// of the sealed job): poll the condition with a generous deadline.
fn wait_for(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let end = Instant::now() + deadline;
    while Instant::now() < end {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    cond()
}

#[test]
fn delete_all_churn_returns_substrate_to_baseline() {
    // The leak-check acceptance bar: a churn of short jobs under
    // RetentionPolicy::DeleteAll must leave blob keys, KV keys, and
    // queue residue at the pre-submit baseline. On the pre-GC head
    // every one of these jobs leaked its whole namespace forever.
    let mgr = JobManager::new(base_cfg(4));
    let base_blob = mgr.store().len();
    let base_kv = mgr.state().scan_prefix("").len();
    assert_eq!((base_blob, base_kv), (0, 0), "fresh substrate");
    let mut rng = Rng::new(0x6C6B);
    for round in 0..6 {
        let a = Matrix::rand_spd(16, &mut rng);
        let (env, inputs, grid) = stage_cholesky(&a, 8).unwrap();
        let job = mgr
            .submit(
                JobSpec::new(programs::cholesky_spec().program, env, inputs)
                    .with_retention(RetentionPolicy::DeleteAll)
                    .with_outputs(["O"]),
            )
            .unwrap();
        let r = mgr.wait(job).unwrap();
        assert_eq!(r.completed, r.total_tasks, "[round {round}]");
        assert!(r.error.is_none(), "[round {round}]");
        let _ = grid;
    }
    assert!(
        wait_for(Duration::from_secs(30), || {
            mgr.store().len() == base_blob
                && mgr.state().scan_prefix("").len() == base_kv
                && mgr.queue_len() == 0
        }),
        "substrate must return to baseline: blobs={} kv={} queue={}",
        mgr.store().len(),
        mgr.state().scan_prefix("").len(),
        mgr.queue_len()
    );
    // The store *did* carry traffic — GC reclaimed keys, not history.
    let fleet = mgr.shutdown();
    assert!(fleet.store.bytes_written > 0);
}

#[test]
fn keep_outputs_retains_outputs_and_reclaims_control_state() {
    let mgr = JobManager::new(base_cfg(4));
    let mut rng = Rng::new(0x0A11);
    let a = Matrix::rand_spd(24, &mut rng);
    let (env, inputs, grid) = stage_cholesky(&a, 8).unwrap();
    let seeds = inputs.len();
    let job = mgr
        .submit(
            JobSpec::new(programs::cholesky_spec().program, env, inputs)
                .with_retention(RetentionPolicy::KeepOutputs)
                .with_outputs(["O"]),
        )
        .unwrap();
    let r = mgr.wait(job).unwrap();
    assert_eq!(r.completed, r.total_tasks);
    // Control state + intermediate tiles go; the O[j,i] outputs stay.
    let n_outputs = grid * (grid + 1) / 2;
    assert!(
        wait_for(Duration::from_secs(30), || {
            mgr.state().scan_prefix("").is_empty() && mgr.store().len() == n_outputs
        }),
        "blobs={} (want {n_outputs} outputs of {} total) kv={}",
        mgr.store().len(),
        seeds as u64 + r.total_tasks,
        mgr.state().scan_prefix("").len()
    );
    // Outputs are still fetchable and exact.
    let fetch = |m: &str, idx: &[i64]| mgr.tile(job, m, idx);
    let l = collect_cholesky(&fetch, a.rows(), 8, grid).unwrap();
    assert!(l.matmul_nt(&l).max_abs_diff(&a) < 1e-8);
}

#[test]
fn dependency_chain_exact_numerics_and_pinned_reclamation() {
    // The chain acceptance bar: cholesky → gemm(L·B) → gemm((L·B)·D)
    // via submit_after read-through imports, with exact numerics at
    // every hop; the KeepOutputs parent's namespace survives while its
    // child consumes it and is reclaimed only after the child is done.
    let mgr = JobManager::new(base_cfg(4));
    let mut rng = Rng::new(0xC4A1);
    let n = 24;
    let block = 8;
    let a = Matrix::rand_spd(n, &mut rng);
    let b = Matrix::randn(n, n, &mut rng);
    let d = Matrix::randn(n, n, &mut rng);

    let (env, inputs, grid) = stage_cholesky(&a, block).unwrap();
    let parent = mgr
        .submit(
            JobSpec::new(programs::cholesky_spec().program, env, inputs)
                .with_retention(RetentionPolicy::KeepOutputs)
                .with_outputs(["O"]),
        )
        .unwrap();

    let (env, inputs, imports, g2) = stage_gemm_after_cholesky(parent, &b, block).unwrap();
    assert_eq!(g2, grid);
    assert!(!imports.is_empty());
    // The child keeps the default KeepAll so its tiles stay fetchable
    // for the numeric check regardless of when the grandchild lands.
    let child = mgr
        .submit_after(
            JobSpec::new(programs::gemm_spec().program, env, inputs)
                .with_outputs(["Ctmp"])
                .with_imports(imports),
            &[parent],
        )
        .unwrap();

    let (env, inputs, imports, g3) = stage_gemm_after_gemm(child, g2, &d, block).unwrap();
    let grandchild = mgr
        .submit_after(
            JobSpec::new(programs::gemm_spec().program, env, inputs)
                .with_outputs(["Ctmp"])
                .with_imports(imports),
            &[child],
        )
        .unwrap();

    // Parent finishes first; while its outputs are pinned by the
    // still-waiting child they must remain resident (the child cannot
    // even have activated yet when this wait returns).
    let rp = mgr.wait(parent).unwrap();
    assert_eq!(rp.completed, rp.total_tasks);
    assert!(
        !mgr.store().scan_prefix(&format!("{parent}/")).is_empty(),
        "pinned parent outputs must survive its own finish"
    );

    let rc = mgr.wait(child).unwrap();
    assert_eq!(rc.completed, rc.total_tasks, "{:?}", rc.error);
    let rg = mgr.wait(grandchild).unwrap();
    assert_eq!(rg.completed, rg.total_tasks, "{:?}", rg.error);

    // Exact numerics at both chained hops.
    let l_ref = factor::cholesky(&a).unwrap();
    let fetch_c = |m: &str, idx: &[i64]| mgr.tile(child, m, idx);
    let lb = collect_gemm(&fetch_c, n, n, block, g2).unwrap();
    assert!(
        lb.max_abs_diff(&l_ref.matmul(&b)) < 1e-9,
        "child must compute exactly L·B"
    );
    let fetch_g = |m: &str, idx: &[i64]| mgr.tile(grandchild, m, idx);
    let lbd = collect_gemm(&fetch_g, n, n, block, g3).unwrap();
    assert!(
        lbd.max_abs_diff(&l_ref.matmul(&b).matmul(&d)) < 1e-8,
        "grandchild must compute exactly (L·B)·D"
    );

    // The consumed KeepOutputs parent is reclaimed once its last (and
    // only) consumer finished; the KeepAll child and grandchild keep
    // their namespaces.
    assert!(
        wait_for(Duration::from_secs(30), || {
            mgr.store().scan_prefix(&format!("{parent}/")).is_empty()
        }),
        "consumed parent must be reclaimed: {} keys left",
        mgr.store().scan_prefix(&format!("{parent}/")).len(),
    );
    assert!(!mgr.store().scan_prefix(&format!("{child}/")).is_empty());
    assert!(!mgr.store().scan_prefix(&format!("{grandchild}/")).is_empty());
}

#[test]
fn max_inflight_quota_prevents_fleet_starvation() {
    // A big *urgent* job capped at 1 in-flight task: its class-1
    // messages outrank everything, so without the quota it would own
    // all 3 workers. With the quota, the class-0 job runs alongside it
    // and finishes while the capped job is still grinding.
    let mut cfg = base_cfg(3);
    cfg.lease = Duration::from_millis(100);
    cfg.store_latency = Duration::from_micros(200);
    let mgr = JobManager::new(cfg);
    let mut rng = Rng::new(0x0F07);
    let big = Matrix::rand_spd(20, &mut rng); // grid 5 → 35 tasks, serialized by the quota
    let (env, inputs, _grid) = stage_cholesky(&big, 4).unwrap();
    let capped = mgr
        .submit(
            JobSpec::new(programs::cholesky_spec().program, env, inputs)
                .with_class(1)
                .with_max_inflight(1),
        )
        .unwrap();
    let sa = Matrix::randn(8, 8, &mut rng);
    let sb = Matrix::randn(8, 8, &mut rng);
    let (env, inputs, sgrid) = stage_gemm(&sa, &sb, 4).unwrap();
    let small = mgr
        .submit(JobSpec::new(programs::gemm_spec().program, env, inputs))
        .unwrap();
    let rs = mgr.wait(small).unwrap();
    assert_eq!(rs.completed, rs.total_tasks);
    assert!(
        matches!(mgr.status(capped), JobStatus::Running { .. }),
        "quota must keep the urgent batch job from starving the fleet"
    );
    let rb = mgr.wait(capped).unwrap();
    assert_eq!(rb.completed, rb.total_tasks, "capped job still completes");
    assert!(
        rs.wall_secs < rb.wall_secs,
        "uncapped small job finishes first ({:.3}s vs {:.3}s)",
        rs.wall_secs,
        rb.wall_secs
    );
    let fetch = |m: &str, idx: &[i64]| mgr.tile(small, m, idx);
    let c = collect_gemm(&fetch, 8, 8, 4, sgrid).unwrap();
    assert!(c.max_abs_diff(&sa.matmul(&sb)) < 1e-9);
}

#[test]
fn eight_jobs_on_autoscaled_fleet() {
    // Heavier multiplexing: 8 small Cholesky jobs against one
    // auto-scaled fleet (the provisioner sees aggregate queue depth).
    let mut cfg = base_cfg(0);
    cfg.scaling = ScalingMode::Auto {
        sf: 1.0,
        max_workers: 8,
    };
    cfg.idle_timeout = Duration::from_millis(60);
    cfg.provision_period = Duration::from_millis(10);
    let mgr = JobManager::new(cfg);
    let mut rng = Rng::new(0x8085);
    let mats: Vec<Matrix> = (0..8).map(|_| Matrix::rand_spd(16, &mut rng)).collect();
    let jobs: Vec<(JobId, usize)> = mats
        .iter()
        .map(|a| {
            let (job, grid, _) = submit_cholesky(&mgr, a, 8, 0);
            (job, grid)
        })
        .collect();
    for ((job, grid), a) in jobs.iter().zip(&mats) {
        let r = mgr.wait(*job).unwrap();
        assert_eq!(r.completed, r.total_tasks, "[{}]", r.job);
        let fetch = |m: &str, idx: &[i64]| mgr.tile(*job, m, idx);
        let l = collect_cholesky(&fetch, a.rows(), 8, *grid).unwrap();
        assert!(l.matmul_nt(&l).max_abs_diff(a) < 1e-8, "[{job:?}]");
    }
    let fleet = mgr.shutdown();
    assert!(fleet.workers_spawned >= 1);
}
