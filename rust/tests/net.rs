//! Wire-protocol battery for the daemon's TCP front door.
//!
//! Four hardening layers, each pinned end-to-end against a real
//! listening daemon:
//!
//! * **Malformed input** — truncated frames, oversized declared
//!   lengths, garbage JSON, mid-frame disconnects, and a slow-loris
//!   trickle. The daemon must never panic, never hang a handler
//!   thread, and never leak a connection (`stats.conns` is the leak
//!   check).
//! * **Concurrency** — ~100 client threads interleaving
//!   submit/status/wait/cancel/stats. Every accepted job completes
//!   with tiles bit-identical to a reference run, job ids never
//!   cross-talk between clients, and wrong/missing auth is rejected
//!   on every op.
//! * **Transport equivalence** — the same 2-job `@jN` chain through
//!   TCP and through the file spool lands bit-identical tiles
//!   (`max_abs_diff == 0.0`) and identical terminal statuses.
//! * **CLI round-trip** — a real `numpywren serve --listen` child
//!   process driven entirely through `--connect` subcommands,
//!   discovering the ephemeral port from the `daemon.json` marker.

use numpywren::config::{EngineConfig, ScalingMode, SubstrateConfig};
use numpywren::daemon::{wire, Daemon, DaemonClient, Json, Request};
use numpywren::jobs::job_prefix;
use numpywren::storage::{BlobStore as _, Substrate};
use numpywren::util::prng::Rng;
use numpywren::JobId;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

const RPC: Duration = Duration::from_secs(30);
const JOB_WAIT: Duration = Duration::from_secs(180);

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("npw_net_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A daemon config listening on an ephemeral localhost port.
fn net_cfg(workers: usize, store: Option<&Path>, auth: Option<&str>) -> EngineConfig {
    let mut cfg = EngineConfig {
        scaling: ScalingMode::Fixed(workers),
        job_timeout: Duration::from_secs(120),
        ..EngineConfig::default()
    };
    cfg.set("listen", "127.0.0.1:0").unwrap();
    if let Some(dir) = store {
        cfg.set("substrate", &format!("file:{}:2", dir.display())).unwrap();
    }
    if let Some(token) = auth {
        cfg.set("auth_token", token).unwrap();
    }
    cfg
}

/// Stand up an in-process daemon on its own thread; returns the bound
/// address and the serve-thread handle (join it after `shutdown`).
fn start(
    cfg: EngineConfig,
    spool: &Path,
) -> (SocketAddr, std::thread::JoinHandle<anyhow::Result<numpywren::FleetReport>>) {
    let d = Daemon::new(cfg, spool).unwrap();
    let addr = d.local_addr().expect("net_cfg always listens");
    (addr, std::thread::spawn(move || d.run()))
}

/// One raw request frame → one decoded JSON response on a throwaway
/// connection (what `DaemonClient` does, minus the conveniences —
/// lets tests send bodies a well-behaved client never would).
fn raw_request(addr: SocketAddr, body: &str) -> Json {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(RPC)).unwrap();
    wire::write_frame(&mut &stream, body).unwrap();
    let rsp = wire::read_frame(&mut &stream).unwrap().expect("response frame");
    Json::parse(&rsp).unwrap()
}

/// Sorted tile keys under one job's namespace.
fn job_tiles(sub: &Substrate, job: JobId) -> Vec<String> {
    let mut keys = sub.blob.scan_prefix(&job_prefix(job));
    keys.sort_unstable();
    keys
}

fn open_store(dir: &Path) -> Substrate {
    let cfg = SubstrateConfig::parse(&format!("file:{}:2", dir.display())).unwrap();
    Substrate::build(&cfg, Duration::from_secs(10), Duration::ZERO)
}

/// Assert two jobs (possibly in different stores, under different
/// ids) hold bit-identical tile sets.
fn assert_tiles_identical(a: (&Substrate, JobId), b: (&Substrate, JobId)) {
    let (sub_a, job_a) = a;
    let (sub_b, job_b) = b;
    let keys_a = job_tiles(sub_a, job_a);
    let keys_b = job_tiles(sub_b, job_b);
    assert!(!keys_a.is_empty(), "{job_a} left no tiles to compare");
    let strip = |keys: &[String], job: JobId| -> Vec<String> {
        keys.iter().map(|k| k[job_prefix(job).len()..].to_string()).collect()
    };
    assert_eq!(strip(&keys_a, job_a), strip(&keys_b, job_b), "{job_a} vs {job_b} key sets");
    for (ka, kb) in keys_a.iter().zip(&keys_b) {
        let ta = sub_a.blob.get(0, ka).unwrap();
        let tb = sub_b.blob.get(0, kb).unwrap();
        assert_eq!(ta.max_abs_diff(&tb), 0.0, "{ka} vs {kb} differ");
    }
}

// ------------------------------------------------------------------
// Satellite 1: malformed-input battery
// ------------------------------------------------------------------

#[test]
fn malformed_frames_never_kill_or_leak() {
    let spool = tmpdir("mal_spool");
    let (addr, server) = start(net_cfg(2, None, None), &spool);

    // (a) Mid-header disconnect: two bytes of a four-byte header.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&[0u8, 0]).unwrap();
    } // dropped: RST/FIN mid-header

    // (b) Declared length over the cap: rejected from the header
    // alone, connection closed without reading the "body".
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.write_all(&((wire::MAX_FRAME + 1) as u32).to_be_bytes()).unwrap();
        s.write_all(b"junk that should never be read").unwrap();
        let mut buf = [0u8; 16];
        let n = s.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "server must close, not answer, an oversized frame");
    }

    // (c) Garbage JSON inside a well-formed frame: a *typed* error
    // response, and the connection survives for the next request.
    {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(RPC)).unwrap();
        wire::write_frame(&mut &s, "{\"op\": ").unwrap();
        let rsp = Json::parse(&wire::read_frame(&mut &s).unwrap().unwrap()).unwrap();
        assert_eq!(rsp.get("ok").and_then(Json::as_bool), Some(false));
        let msg = rsp.get("error").and_then(Json::as_str).unwrap();
        assert!(msg.contains("bad request"), "{msg}");
        // Same connection, now a legal request: still served.
        wire::write_frame(&mut &s, &Request::Stats.encode()).unwrap();
        let rsp = Json::parse(&wire::read_frame(&mut &s).unwrap().unwrap()).unwrap();
        assert_eq!(rsp.get("ok").and_then(Json::as_bool), Some(true));
    }

    // (d) Unknown op and bad specs: typed errors, never a hang.
    let rsp = raw_request(addr, "{\"op\":\"fry\"}");
    assert!(rsp.get("error").and_then(Json::as_str).unwrap().contains("unknown op"));
    let rsp = raw_request(addr, "{\"op\":\"submit\",\"specs\":\"cholesky:16\"}");
    assert_eq!(rsp.get("ok").and_then(Json::as_bool), Some(false));

    // (e) Mid-body disconnect: declare 100 bytes, send 40, vanish.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&100u32.to_be_bytes()).unwrap();
        s.write_all(&[b'x'; 40]).unwrap();
    }

    // (f) Slow-loris: trickle header bytes slower than the frame
    // deadline. The server must cut the connection off (~2s), not pin
    // a handler thread forever.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
        s.write_all(&[0u8]).unwrap();
        let cut_by = Instant::now() + Duration::from_secs(15);
        loop {
            std::thread::sleep(Duration::from_millis(300));
            // Detect the close from either direction: a read that
            // returns EOF, or a write that fails (EPIPE/ECONNRESET).
            let mut buf = [0u8; 1];
            match s.read(&mut buf) {
                Ok(0) => break,
                Ok(_) => panic!("server answered an unfinished header"),
                Err(_) => {}
            }
            if s.write_all(&[0u8]).is_err() {
                break;
            }
            assert!(Instant::now() < cut_by, "slow-loris connection never cut off");
        }
    }

    // (g) Seeded random garbage, raw on the socket.
    let mut rng = Rng::new(0xBADC_0DE);
    for _ in 0..16 {
        let n = 1 + rng.below(64);
        let junk: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        let mut s = TcpStream::connect(addr).unwrap();
        let _ = s.write_all(&junk);
    }

    // After the whole battery the daemon still serves, and exactly one
    // connection (ours, carrying the stats request) is live — every
    // battery connection's handler thread has exited.
    let client = DaemonClient::connect(addr.to_string(), None);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stats = client.stats(RPC).unwrap();
        if stats.conns == 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "handler threads leaked: {} connections still live",
            stats.conns
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    client.shutdown(RPC).unwrap();
    server.join().unwrap().unwrap();
}

// ------------------------------------------------------------------
// Auth: wrong/missing token rejected on every op
// ------------------------------------------------------------------

#[test]
fn auth_is_enforced_on_every_op() {
    let spool = tmpdir("auth_spool");
    let (addr, server) = start(net_cfg(2, None, Some("s3cret")), &spool);

    let good = DaemonClient::connect(addr.to_string(), Some("s3cret".into()));
    let wrong = DaemonClient::connect(addr.to_string(), Some("nope".into()));
    let missing = DaemonClient::connect(addr.to_string(), None);

    let jobs = good.submit("cholesky:12:4", 5, None, None, RPC).unwrap();
    assert_eq!(jobs, vec![JobId(1)]);

    for (client, expect) in [
        (&wrong, "unauthorized: bad `auth` token"),
        (&missing, "unauthorized: request carries no `auth` token"),
    ] {
        let ops = [
            Request::Submit {
                specs: "cholesky:12:4".into(),
                seed: 5,
                retention: None,
                max_inflight: None,
            },
            Request::Status { job: JobId(1) },
            Request::Wait { job: JobId(1), timeout_ms: 1000 },
            Request::Cancel { job: JobId(1) },
            Request::Stats,
            Request::Shutdown,
        ];
        for op in ops {
            let err = client.request(&op, RPC).unwrap_err().to_string();
            assert_eq!(err, expect, "op {op:?}");
        }
    }
    // An unauthorized `shutdown` must not have stopped the daemon, and
    // an unauthenticated caller learns nothing about job validity.
    let st = good.wait_terminal(JobId(1), JOB_WAIT).unwrap();
    assert_eq!(st.state, "succeeded", "{:?}", st.error);

    good.shutdown(RPC).unwrap();
    server.join().unwrap().unwrap();
}

// ------------------------------------------------------------------
// Server-side wait semantics
// ------------------------------------------------------------------

#[test]
fn wait_parks_server_side_and_reports_terminal() {
    let spool = tmpdir("wait_spool");
    let (addr, server) = start(net_cfg(2, None, None), &spool);
    let client = DaemonClient::connect(addr.to_string(), None);

    // max_inflight=1 serializes the tasks so the job is reliably still
    // running when the short wait below expires.
    let jobs = client.submit("cholesky:24:8", 7, None, Some(1), RPC).unwrap();
    let rsp = client.request(&Request::Wait { job: jobs[0], timeout_ms: 30 }, RPC).unwrap();
    // The response always carries `terminal`; with a 30ms deadline on
    // a serialized job it reports a non-terminal snapshot (if the tiny
    // job somehow won the race, terminal=true is the honest answer).
    let terminal = rsp.get("terminal").and_then(Json::as_bool).unwrap();
    let state = rsp.get("state").and_then(Json::as_str).unwrap();
    assert_eq!(terminal, matches!(state, "succeeded" | "failed" | "canceled"), "{state}");

    // The long-poll path converges to terminal.
    let st = client.wait_terminal(jobs[0], JOB_WAIT).unwrap();
    assert_eq!(st.state, "succeeded", "{:?}", st.error);
    // Terminal job: wait answers immediately, terminal=true.
    let t0 = Instant::now();
    let rsp = client.request(&Request::Wait { job: jobs[0], timeout_ms: 60_000 }, RPC).unwrap();
    assert_eq!(rsp.get("terminal").and_then(Json::as_bool), Some(true));
    assert!(t0.elapsed() < Duration::from_secs(5), "wait on a terminal job must not park");
    // Unknown jobs settle immediately too (never a 30s park).
    let t0 = Instant::now();
    let rsp = client.request(&Request::Wait { job: JobId(99), timeout_ms: 60_000 }, RPC).unwrap();
    assert_eq!(rsp.get("state").and_then(Json::as_str), Some("unknown"));
    assert!(t0.elapsed() < Duration::from_secs(5));
    assert!(client.wait_terminal(JobId(99), RPC).is_err(), "unknown job errors client-side");

    client.shutdown(RPC).unwrap();
    server.join().unwrap().unwrap();
}

// ------------------------------------------------------------------
// Satellite 2: ~100-client concurrent stress with exact numerics
// ------------------------------------------------------------------

#[test]
fn hundred_concurrent_clients_no_crosstalk_exact_numerics() {
    const CLIENTS: usize = 100;
    const TOKEN: &str = "stress-token";
    // Four distinct workloads cycled across the clients; each entry is
    // (spec, seed) — the daemon derives per-job seeds from these, so
    // every client running combo k must land tiles bit-identical to
    // the reference daemon's job for combo k.
    const COMBOS: [(&str, u64); 4] =
        [("cholesky:12:4", 5), ("cholesky:16:8", 7), ("gemm:12:4", 9), ("gemm:16:8", 11)];

    // Reference run: one spool-only daemon, the four combos submitted
    // sequentially as j1..j4.
    let ref_spool = tmpdir("stress_ref_spool");
    let ref_store = tmpdir("stress_ref_store");
    {
        let mut cfg = EngineConfig {
            scaling: ScalingMode::Fixed(2),
            job_timeout: Duration::from_secs(120),
            ..EngineConfig::default()
        };
        cfg.set("substrate", &format!("file:{}:2", ref_store.display())).unwrap();
        let d = Daemon::new(cfg, &ref_spool).unwrap();
        let server = std::thread::spawn(move || d.run());
        let client = DaemonClient::new(&ref_spool);
        for (k, (spec, seed)) in COMBOS.iter().enumerate() {
            let jobs = client.submit(spec, *seed, None, None, RPC).unwrap();
            assert_eq!(jobs, vec![JobId(k as u64 + 1)]);
        }
        for k in 1..=COMBOS.len() as u64 {
            let st = client.wait_terminal(JobId(k), JOB_WAIT).unwrap();
            assert_eq!(st.state, "succeeded", "reference j{k}: {:?}", st.error);
        }
        client.shutdown(RPC).unwrap();
        server.join().unwrap().unwrap();
    }

    // Stress run: one TCP daemon, CLIENTS threads interleaving ops.
    let spool = tmpdir("stress_spool");
    let store = tmpdir("stress_store");
    let (addr, server) = start(net_cfg(4, Some(&store), Some(TOKEN)), &spool);

    let handles: Vec<_> = (0..CLIENTS)
        .map(|i| {
            let addr = addr.to_string();
            std::thread::spawn(move || -> (usize, JobId) {
                let combo = i % COMBOS.len();
                let (spec, seed) = COMBOS[combo];
                let client = DaemonClient::connect(addr, Some(TOKEN.into()));
                let jobs = client.submit(spec, seed, None, None, RPC).unwrap();
                assert_eq!(jobs.len(), 1, "client {i}");
                let job = jobs[0];
                // Interleave the other ops while the job runs.
                let st = client.status(job, RPC).unwrap();
                assert_eq!(st.job, job);
                if i % 7 == 0 {
                    let stats = client.stats(RPC).unwrap();
                    assert!(stats.conns >= 1);
                }
                let st = client.wait_terminal(job, JOB_WAIT).unwrap();
                assert_eq!(st.state, "succeeded", "client {i} {job}: {:?}", st.error);
                // Cancel after terminal: a definitive no, not cross-talk
                // onto some other client's still-running job.
                assert!(!client.cancel(job, RPC).unwrap(), "client {i} canceled a terminal job");
                (combo, job)
            })
        })
        .collect();
    let results: Vec<(usize, JobId)> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // No response cross-talk: every client got its own distinct job
    // id, and together they cover j1..j100 exactly.
    let mut ids: Vec<u64> = results.iter().map(|(_, j)| j.0).collect();
    ids.sort_unstable();
    assert_eq!(ids, (1..=CLIENTS as u64).collect::<Vec<_>>());

    let client = DaemonClient::connect(addr.to_string(), Some(TOKEN.into()));
    let stats = client.stats(RPC).unwrap();
    assert_eq!(stats.active, 0, "all jobs terminal");
    client.shutdown(RPC).unwrap();
    server.join().unwrap().unwrap();

    // Exact numerics: every stress job's tiles are bit-identical to
    // the reference job of its combo.
    let stress_sub = open_store(&store);
    let ref_sub = open_store(&ref_store);
    for (combo, job) in &results {
        assert_tiles_identical((&stress_sub, *job), (&ref_sub, JobId(*combo as u64 + 1)));
    }
}

// ------------------------------------------------------------------
// Satellite 3: transport equivalence (TCP vs file spool)
// ------------------------------------------------------------------

#[test]
fn tcp_and_spool_transports_are_bit_identical() {
    let specs = [("cholesky:16:8", 7u64), ("gemm:16:8@j1", 11u64)];

    // Leg 1: file spool only.
    let spool_a = tmpdir("equiv_a_spool");
    let store_a = tmpdir("equiv_a_store");
    let mut statuses_a = Vec::new();
    {
        let mut cfg = EngineConfig {
            scaling: ScalingMode::Fixed(2),
            job_timeout: Duration::from_secs(120),
            ..EngineConfig::default()
        };
        cfg.set("substrate", &format!("file:{}:2", store_a.display())).unwrap();
        let d = Daemon::new(cfg, &spool_a).unwrap();
        let server = std::thread::spawn(move || d.run());
        let client = DaemonClient::new(&spool_a);
        for (k, (spec, seed)) in specs.iter().enumerate() {
            let jobs = client.submit(spec, *seed, None, None, RPC).unwrap();
            assert_eq!(jobs, vec![JobId(k as u64 + 1)]);
        }
        for k in 1..=specs.len() as u64 {
            let st = client.wait_terminal(JobId(k), JOB_WAIT).unwrap();
            statuses_a.push(st.state.clone());
            assert_eq!(st.state, "succeeded", "spool j{k}: {:?}", st.error);
        }
        client.shutdown(RPC).unwrap();
        server.join().unwrap().unwrap();
    }

    // Leg 2: the same chain over TCP.
    let spool_b = tmpdir("equiv_b_spool");
    let store_b = tmpdir("equiv_b_store");
    let mut statuses_b = Vec::new();
    {
        let (addr, server) = start(net_cfg(2, Some(&store_b), None), &spool_b);
        let client = DaemonClient::connect(addr.to_string(), None);
        for (k, (spec, seed)) in specs.iter().enumerate() {
            let jobs = client.submit(spec, *seed, None, None, RPC).unwrap();
            assert_eq!(jobs, vec![JobId(k as u64 + 1)]);
        }
        for k in 1..=specs.len() as u64 {
            let st = client.wait_terminal(JobId(k), JOB_WAIT).unwrap();
            statuses_b.push(st.state.clone());
        }
        client.shutdown(RPC).unwrap();
        server.join().unwrap().unwrap();
    }

    assert_eq!(statuses_a, statuses_b, "terminal statuses must match across transports");
    let sub_a = open_store(&store_a);
    let sub_b = open_store(&store_b);
    for k in 1..=specs.len() as u64 {
        assert_tiles_identical((&sub_a, JobId(k)), (&sub_b, JobId(k)));
    }
}

// ------------------------------------------------------------------
// CLI round-trip over --connect (real child process)
// ------------------------------------------------------------------

#[cfg(target_os = "linux")]
#[test]
fn cli_drives_a_tcp_daemon_end_to_end() {
    use std::process::{Command, Stdio};
    const BIN: &str = env!("CARGO_BIN_EXE_numpywren");

    let spool = tmpdir("cli_spool");
    let mut child = Command::new(BIN)
        .args([
            "serve",
            "--daemon-dir",
            &spool.display().to_string(),
            "--listen",
            "127.0.0.1:0",
            "--auth-token",
            "cli-token",
            "--workers",
            "2",
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning numpywren serve");

    // Discover the ephemeral port from the marker's "addr" field.
    let marker = spool.join("daemon.json");
    let deadline = Instant::now() + Duration::from_secs(30);
    let addr = loop {
        if let Ok(body) = std::fs::read_to_string(&marker) {
            let got = Json::parse(&body)
                .ok()
                .and_then(|v| v.get("addr").and_then(Json::as_str).map(str::to_string));
            if let Some(addr) = got {
                break addr;
            }
        }
        assert!(Instant::now() < deadline, "marker never published an addr");
        std::thread::sleep(Duration::from_millis(20));
    };

    let run = |args: &[&str]| {
        Command::new(BIN)
            .args(args)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .status()
            .unwrap()
    };
    let connect = ["--connect", &addr, "--auth-token", "cli-token"];

    // submit --wait runs the job to terminal over TCP.
    let mut submit: Vec<&str> =
        vec!["submit", "--specs", "cholesky:12:4", "--wait", "true", "--wait-timeout", "120"];
    submit.extend_from_slice(&connect);
    assert!(run(&submit).success(), "submit --connect failed");

    // status / wait / cancel over --connect.
    let mut status: Vec<&str> = vec!["status", "--job", "j1"];
    status.extend_from_slice(&connect);
    assert!(run(&status).success());
    let mut wait: Vec<&str> = vec!["wait", "--job", "j1", "--wait-timeout", "60"];
    wait.extend_from_slice(&connect);
    assert!(run(&wait).success());

    // Wrong token fails loudly; the daemon stays up.
    let status = run(&["status", "--job", "j1", "--connect", &addr, "--auth-token", "oops"]);
    assert!(!status.success(), "wrong token must be rejected");

    let mut shutdown: Vec<&str> = vec!["shutdown"];
    shutdown.extend_from_slice(&connect);
    assert!(run(&shutdown).success());
    let code = child.wait().expect("serve child");
    assert!(code.success(), "serve exited with {code:?}");
}
