//! Property tests for the LAmbdaPACK dependency analyzer (the paper's
//! core contribution): on *randomly generated* programs — random loop
//! nests, affine and `2**var` index expressions, `if` guards — the
//! analyzer's `find_readers`/`find_writers` must agree exactly with
//! brute-force enumeration of the whole iteration space.

use numpywren::lambdapack::analysis::{Analyzer, Loc};
use numpywren::lambdapack::ast::{Cop, Expr, IdxExpr, Program, Stmt};
use numpywren::lambdapack::interp::{enumerate_nodes, Env, Node};
use numpywren::util::prng::Rng;
use std::collections::{BTreeMap, BTreeSet};

const MATRICES: &[&str] = &["A", "B", "C"];
const VARS: &[&str] = &["i", "j", "k"];

/// A random affine-ish index expression over the in-scope vars.
fn rand_index(rng: &mut Rng, scope: &[String]) -> Expr {
    if scope.is_empty() {
        return Expr::int(rng.range_i64(0, 3));
    }
    let v = scope[rng.below(scope.len())].clone();
    match rng.below(6) {
        0 => Expr::var(&v),
        1 => Expr::add(Expr::var(&v), Expr::int(rng.range_i64(-1, 2))),
        2 => Expr::mul(Expr::int(rng.range_i64(1, 2)), Expr::var(&v)),
        3 => {
            // two-variable affine when possible
            let w = scope[rng.below(scope.len())].clone();
            Expr::add(Expr::var(&v), Expr::var(&w))
        }
        4 => Expr::pow2(Expr::var(&v)), // the nonlinear class
        _ => Expr::int(rng.range_i64(0, 3)),
    }
}

fn rand_idx_expr(rng: &mut Rng, scope: &[String]) -> IdxExpr {
    let m = MATRICES[rng.below(MATRICES.len())];
    let arity = 1 + rng.below(2);
    IdxExpr::new(
        m,
        (0..arity).map(|_| rand_index(rng, scope)).collect(),
    )
}

fn rand_body(rng: &mut Rng, depth: usize, scope: &mut Vec<String>, lines: &mut usize) -> Vec<Stmt> {
    let mut body = Vec::new();
    let n_stmts = 1 + rng.below(2);
    for _ in 0..n_stmts {
        if *lines >= 5 {
            break;
        }
        let choice = rng.below(if depth < 3 { 4 } else { 2 });
        match choice {
            // kernel call
            0 | 1 => {
                *lines += 1;
                body.push(Stmt::KernelCall {
                    line: usize::MAX,
                    fn_name: "op".into(),
                    outputs: vec![rand_idx_expr(rng, scope)],
                    mat_inputs: (0..1 + rng.below(2))
                        .map(|_| rand_idx_expr(rng, scope))
                        .collect(),
                    scalar_inputs: vec![],
                });
            }
            // loop
            2 => {
                let var = VARS[depth % VARS.len()].to_string();
                if scope.contains(&var) {
                    continue;
                }
                let lo = rng.range_i64(0, 1);
                let hi = lo + rng.range_i64(1, 4);
                scope.push(var.clone());
                let inner = rand_body(rng, depth + 1, scope, lines);
                scope.pop();
                if inner.is_empty() {
                    continue;
                }
                body.push(Stmt::For {
                    var,
                    min: Expr::int(lo),
                    max: if rng.chance(0.3) && !scope.is_empty() {
                        // bound depending on an outer var
                        Expr::add(
                            Expr::var(&scope[rng.below(scope.len())]),
                            Expr::int(rng.range_i64(1, 3)),
                        )
                    } else {
                        Expr::int(hi)
                    },
                    step: Expr::int(if rng.chance(0.2) { 2 } else { 1 }),
                    body: inner,
                });
            }
            // guard
            _ => {
                if scope.is_empty() {
                    continue;
                }
                let v = scope[rng.below(scope.len())].clone();
                let inner = rand_body(rng, depth + 1, scope, lines);
                let else_inner = if rng.chance(0.3) {
                    rand_body(rng, depth + 1, scope, lines)
                } else {
                    vec![]
                };
                if inner.is_empty() && else_inner.is_empty() {
                    continue;
                }
                body.push(Stmt::If {
                    cond: Expr::Cmp(
                        Cop::Lt,
                        Box::new(Expr::var(&v)),
                        Box::new(Expr::int(rng.range_i64(1, 3))),
                    ),
                    body: inner,
                    else_body: else_inner,
                });
            }
        }
    }
    body
}

fn rand_program(rng: &mut Rng) -> Program {
    let mut lines = 0;
    let mut scope = Vec::new();
    let mut body = rand_body(rng, 0, &mut scope, &mut lines);
    if lines == 0 {
        // Guarantee at least one node.
        body.push(Stmt::KernelCall {
            line: usize::MAX,
            fn_name: "op".into(),
            outputs: vec![IdxExpr::new("A", vec![Expr::int(0)])],
            mat_inputs: vec![IdxExpr::new("B", vec![Expr::int(0)])],
            scalar_inputs: vec![],
        });
    }
    Program::new("fuzz", &[], MATRICES, body)
}

/// Ground truth by full enumeration.
fn brute_force(
    program: &Program,
    analyzer: &Analyzer,
) -> (BTreeMap<Loc, BTreeSet<Node>>, BTreeMap<Loc, BTreeSet<Node>>) {
    let mut readers: BTreeMap<Loc, BTreeSet<Node>> = BTreeMap::new();
    let mut writers: BTreeMap<Loc, BTreeSet<Node>> = BTreeMap::new();
    let env = Env::new();
    enumerate_nodes(program, &env, &mut |node, _| {
        let task = analyzer.concretize(node).unwrap();
        for r in &task.reads {
            readers.entry(r.clone()).or_default().insert(node.clone());
        }
        for w in &task.writes {
            writers.entry(w.clone()).or_default().insert(node.clone());
        }
    })
    .unwrap();
    (readers, writers)
}

#[test]
fn analyzer_matches_brute_force_on_random_programs() {
    let cases: usize = std::env::var("NUMPYWREN_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let program = rand_program(&mut rng);
        let env = Env::new();
        let analyzer = Analyzer::new(&program, &env);
        let (readers, writers) = brute_force(&program, &analyzer);
        // Check every location that is actually touched…
        for (loc, expect) in &readers {
            let got: BTreeSet<Node> =
                analyzer.find_readers(loc).unwrap().into_iter().collect();
            assert_eq!(
                &got, expect,
                "readers mismatch at {loc} (case {case}, seed {seed:#x})\nprogram: {program:#?}"
            );
        }
        for (loc, expect) in &writers {
            let got: BTreeSet<Node> =
                analyzer.find_writers(loc).unwrap().into_iter().collect();
            assert_eq!(
                &got, expect,
                "writers mismatch at {loc} (case {case}, seed {seed:#x})\nprogram: {program:#?}"
            );
        }
        // …and some that are not (must return empty, not error).
        for probe in 0..5 {
            let m = MATRICES[probe % MATRICES.len()];
            let loc = Loc::new(m, vec![rng.range_i64(90, 99)]);
            if !readers.contains_key(&loc) {
                assert!(
                    analyzer.find_readers(&loc).unwrap().is_empty(),
                    "phantom readers at {loc} (case {case})"
                );
            }
            if !writers.contains_key(&loc) {
                assert!(
                    analyzer.find_writers(&loc).unwrap().is_empty(),
                    "phantom writers at {loc} (case {case})"
                );
            }
        }
    }
}

#[test]
fn children_parents_duality_on_random_programs() {
    // For every edge (p → c) reported by children(), parents(c) must
    // contain p, and vice versa — on random programs.
    for case in 0..60usize {
        let seed = 0xD0A1 ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let program = rand_program(&mut rng);
        let env = Env::new();
        let analyzer = Analyzer::new(&program, &env);
        let mut nodes = Vec::new();
        enumerate_nodes(&program, &env, &mut |n, _| nodes.push(n.clone())).unwrap();
        for n in &nodes {
            for c in analyzer.children(n).unwrap() {
                let ps = analyzer.parents(&c).unwrap();
                assert!(
                    ps.contains(n),
                    "child {} of {} does not list it as parent (case {case})",
                    c.id(),
                    n.id()
                );
            }
            for p in analyzer.parents(n).unwrap() {
                let cs = analyzer.children(&p).unwrap();
                assert!(
                    cs.contains(n),
                    "parent {} of {} does not list it as child (case {case})",
                    p.id(),
                    n.id()
                );
            }
        }
    }
}
