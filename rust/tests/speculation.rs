//! Deterministic straggler-speculation tests on a [`TestClock`].
//!
//! The scenario the ISSUE pins: a worker claims a task and then stalls
//! (here: its kernel blocks on a gate, standing in for a slow Lambda),
//! virtual time advances past the straggler threshold, and the job
//! manager's monitor enqueues a bounded speculative duplicate. Either
//! attempt may finish first; the completion CAS lets exactly one win,
//! SSA single-writer re-puts are bit-identical, and the output must
//! equal an unspeculated run exactly — `max_abs_diff == 0.0`, not a
//! tolerance.
//!
//! Nothing here depends on wall-clock timing: leases are 3600 virtual
//! seconds (so lease-expiry redelivery can never be the rescuer) and
//! the straggler threshold is crossed only by explicit
//! `TestClock::advance` calls.

use numpywren::config::{EngineConfig, ScalingMode, SubstrateConfig};
use numpywren::drivers::{collect_cholesky, stage_cholesky};
use numpywren::jobs::{JobManager, JobReport, JobSpec, JobStatus};
use numpywren::kernels::{KernelExecutor, NativeKernels};
use numpywren::lambdapack::programs;
use numpywren::linalg::matrix::Matrix;
use numpywren::storage::TestClock;
use numpywren::util::prng::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Delegates to [`NativeKernels`] except that the FIRST `execute`
/// call fleet-wide blocks on a gate until the test releases it — a
/// deterministic straggler. With a tiny Cholesky the first executed
/// task is the root factorization, so the whole DAG is stuck behind
/// the gate until either the speculative duplicate runs it on the
/// other worker or the gate opens.
struct GateKernels {
    inner: NativeKernels,
    armed: AtomicBool,
    open: Mutex<bool>,
    cv: Condvar,
}

impl GateKernels {
    fn new() -> Arc<GateKernels> {
        Arc::new(GateKernels {
            inner: NativeKernels,
            armed: AtomicBool::new(true),
            open: Mutex::new(false),
            cv: Condvar::new(),
        })
    }

    /// Open the gate; the stalled worker resumes. Always call before
    /// shutdown or the pool join hangs on the blocked compute thread.
    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

impl KernelExecutor for GateKernels {
    fn execute(
        &self,
        fn_name: &str,
        inputs: &[Arc<Matrix>],
        scalars: &[f64],
    ) -> anyhow::Result<Vec<Matrix>> {
        if self.armed.swap(false, Ordering::SeqCst) {
            let mut open = self.open.lock().unwrap();
            while !*open {
                open = self.cv.wait(open).unwrap();
            }
        }
        self.inner.execute(fn_name, inputs, scalars)
    }
}

/// Two workers, virtual time, deterministic substrate (the CI
/// substrate matrix is deliberately NOT honored here — chaos wrappers
/// would blur the "exactly one duplicate source" accounting).
fn spec_cfg(spec_max: usize) -> EngineConfig {
    EngineConfig {
        scaling: ScalingMode::Fixed(2),
        substrate: SubstrateConfig::parse("sharded:2").unwrap(),
        // Leases never expire within the test's virtual horizon:
        // redelivery cannot masquerade as speculation.
        lease: Duration::from_secs(3600),
        spec_max,
        job_timeout: Duration::from_secs(300),
        ..EngineConfig::default()
    }
}

fn run_gated(spec_max: usize, a: &Matrix) -> (JobReport, Matrix) {
    let clock = Arc::new(TestClock::default());
    let gate = GateKernels::new();
    let mgr = JobManager::with_kernels_and_clock(
        spec_cfg(spec_max),
        gate.clone() as Arc<dyn KernelExecutor>,
        clock.clone(),
    );
    let (env, inputs, grid) = stage_cholesky(a, 8).unwrap();
    let job = mgr
        .submit(JobSpec::new(programs::cholesky_spec().program, env, inputs))
        .unwrap();

    if spec_max > 0 {
        // Advance virtual time until the monitor speculates: once a
        // worker holds the gated root, its claim age crosses the cold
        // threshold (0.5 virtual seconds) and a duplicate lands in the
        // queue. The root's own message stays leased (3600 s), so a
        // depth of 2 can only mean the duplicate was enqueued.
        let deadline = Instant::now() + Duration::from_secs(60);
        while mgr.queue_len() < 2 && mgr.status(job) != JobStatus::Succeeded {
            assert!(Instant::now() < deadline, "monitor never speculated");
            clock.advance(Duration::from_millis(100));
            std::thread::sleep(Duration::from_millis(3));
        }
        // Let the race run: if the free worker claimed the duplicate it
        // finishes the whole job while the original is still gated. If
        // the gated worker's own read stage swallowed the duplicate
        // instead, the job stays stuck — both outcomes are legitimate
        // "first completion wins" executions, settled below by opening
        // the gate.
        let grace = Instant::now() + Duration::from_secs(3);
        while mgr.status(job) != JobStatus::Succeeded && Instant::now() < grace {
            std::thread::sleep(Duration::from_millis(3));
        }
    } else {
        // With speculation disabled, no amount of virtual lateness may
        // produce a duplicate: the root's message stays the only one.
        for _ in 0..40 {
            clock.advance(Duration::from_millis(200));
            std::thread::sleep(Duration::from_millis(2));
            assert!(mgr.queue_len() <= 1, "speculated with spec_max=0");
        }
        assert_eq!(mgr.queue_len(), 1, "root message went missing");
        assert!(matches!(mgr.status(job), JobStatus::Running { .. }));
    }

    gate.release();
    let report = mgr.wait(job).unwrap();
    let fetch = |m: &str, idx: &[i64]| mgr.tile(job, m, idx);
    let l = collect_cholesky(&fetch, a.rows(), 8, grid).unwrap();
    mgr.shutdown();
    (report, l)
}

/// Unspeculated, ungated reference run of the same staging.
fn run_reference(a: &Matrix) -> Matrix {
    let mgr = JobManager::new(spec_cfg(0));
    let (env, inputs, grid) = stage_cholesky(a, 8).unwrap();
    let job = mgr
        .submit(JobSpec::new(programs::cholesky_spec().program, env, inputs))
        .unwrap();
    mgr.wait(job).unwrap();
    let fetch = |m: &str, idx: &[i64]| mgr.tile(job, m, idx);
    let l = collect_cholesky(&fetch, a.rows(), 8, grid).unwrap();
    mgr.shutdown();
    l
}

#[test]
fn speculative_duplicate_races_straggler_to_an_exact_output() {
    let mut rng = Rng::new(0x5bec);
    let a = Matrix::rand_spd(16, &mut rng);
    let reference = run_reference(&a);

    let (report, l) = run_gated(4, &a);
    assert!(report.error.is_none(), "{:?}", report.error);
    assert_eq!(report.completed, report.total_tasks);
    // Speculation actually fired, and stayed within budget.
    assert!(
        (1..=4).contains(&report.spec_enqueued),
        "spec_enqueued = {}",
        report.spec_enqueued
    );
    // Exactly one output version: duplicates re-put bit-identical SSA
    // tiles and only one finisher wins the completion CAS, so the
    // factor matches the unspeculated run bit-for-bit.
    assert_eq!(l.max_abs_diff(&reference), 0.0, "speculated run diverged");
    assert!(l.matmul_nt(&l).max_abs_diff(&a) < 1e-8, "LLᵀ ≠ A");
}

#[test]
fn spec_max_zero_never_speculates() {
    let mut rng = Rng::new(0x5bec);
    let a = Matrix::rand_spd(16, &mut rng);
    let reference = run_reference(&a);

    let (report, l) = run_gated(0, &a);
    assert!(report.error.is_none(), "{:?}", report.error);
    assert_eq!(report.completed, report.total_tasks);
    assert_eq!(report.spec_enqueued, 0);
    assert_eq!(l.max_abs_diff(&reference), 0.0);
}
