//! Substrate conformance suite — every backend family must provide the
//! same semantics through the `storage::traits` interfaces.
//!
//! Each test runs against all shipped backends (strict single-lock and
//! sharded at several shard counts) through `Arc<dyn …>` handles only,
//! exactly as the engine holds them. Concurrency tests hammer the
//! linearizable primitives (`cas`, `set_nx`, `edge_decr`) and the
//! queue's lease machinery; the ordering tests pin the
//! FIFO-within-priority contract on the backends that guarantee it
//! globally (strict, and sharded with one shard).

use numpywren::config::{EngineConfig, ScalingMode, SubstrateConfig};
use numpywren::drivers;
use numpywren::engine::Engine;
use numpywren::linalg::matrix::Matrix;
use numpywren::storage::{BlobStore as _, KvState as _, Queue as _, Substrate, TestClock};
use numpywren::util::prng::Rng;
use std::sync::Arc;
use std::time::Duration;

const LEASE: Duration = Duration::from_secs(10);

/// All backend families, built on a deterministic test clock. The
/// chaos-wrapped entries exercise the decorator layer with pure
/// latency shaping (zero fault probabilities): the decorators must
/// preserve every trait contract bit-for-bit — they perturb timing,
/// never semantics. The cache-wrapped entries pin the same bar for
/// the worker-local tile cache (read results and lifecycle semantics
/// unchanged; only the read *accounting* legitimately differs — see
/// `blob_read_after_write_and_accounting`).
fn backends() -> Vec<(&'static str, Substrate, Arc<TestClock>)> {
    [
        "strict",
        "sharded:1",
        "sharded:4",
        "sharded:16",
        // `auto` resolves its shard count from the environment at
        // build time; the contracts must hold at whatever count it
        // picks.
        "sharded:auto",
        "strict+chaos(lat=fixed:20us,recv_lat=10us,kv_lat=5us,seed=3)",
        "sharded:4+chaos(lat=uniform:5us:50us,straggle=0.25:4,seed=5)",
        "sharded:4+chaos(send_lat=5us,seed=7)",
        "sharded:4+cache(bytes=1048576)",
        "sharded:4+cache(bytes=2m)+chaos(lat=fixed:10us,seed=9)",
        // The durable on-disk family. `auto` materializes a fresh
        // temp directory per build (per-test isolation); the same
        // contracts must hold with state living in files, and the
        // decorators must compose over it unchanged.
        "file:auto",
        "file:auto:4",
        "file:auto+chaos(lat=fixed:20us,kv_lat=5us,seed=31)",
        "file:auto+cache(bytes=1048576)",
        // Clock skew: the queue backends see time through a lens offset
        // from the fleet's clock. Every contract must hold unchanged —
        // take and expiry read the same skewed handle, so a constant
        // offset cancels. (Positive skew here; negative skew would
        // saturate at a fresh TestClock's epoch — the dedicated
        // regression test below advances past the offset first.)
        "sharded:4+chaos(skew=3s,seed=41)",
        "file:auto+chaos(skew=3s,seed=43)",
    ]
    .into_iter()
    .map(|spec| {
        let clock = Arc::new(TestClock::default());
        let cfg = SubstrateConfig::parse(spec).unwrap();
        let sub = Substrate::build_with_clock(&cfg, LEASE, Duration::ZERO, clock.clone());
        (spec, sub, clock)
    })
    .collect()
}

/// The backends that guarantee *global* priority + FIFO ordering.
fn ordered_backends() -> Vec<(&'static str, Substrate, Arc<TestClock>)> {
    backends()
        .into_iter()
        .filter(|(spec, _, _)| {
            *spec == "strict"
                || *spec == "sharded:1"
                || (spec.starts_with("file:") && !spec.contains('+'))
        })
        .collect()
}

// ---------- KvState ----------

#[test]
fn kv_cas_exactly_one_winner_concurrent() {
    for (spec, sub, _) in backends() {
        let state = sub.state;
        let mut handles = Vec::new();
        for _ in 0..16 {
            let state = state.clone();
            handles.push(std::thread::spawn(move || {
                state.cas("status:t", None, "completed")
            }));
        }
        let wins: usize = handles
            .into_iter()
            .map(|h| h.join().unwrap() as usize)
            .sum();
        assert_eq!(wins, 1, "[{spec}] exactly one CAS winner");
        assert_eq!(state.get("status:t").as_deref(), Some("completed"));
    }
}

#[test]
fn kv_set_nx_exactly_one_winner_concurrent() {
    for (spec, sub, _) in backends() {
        let state = sub.state;
        let mut handles = Vec::new();
        for i in 0..16 {
            let state = state.clone();
            handles.push(std::thread::spawn(move || {
                state.set_nx("job:error", &format!("worker {i}"))
            }));
        }
        let wins: usize = handles
            .into_iter()
            .map(|h| h.join().unwrap() as usize)
            .sum();
        assert_eq!(wins, 1, "[{spec}] exactly one set_nx winner");
    }
}

#[test]
fn kv_edge_decr_idempotent_and_exact_concurrent() {
    // N distinct parents, each decrementing its edge 3 times from
    // separate threads: the counter must land on exactly 0, at least
    // one caller must observe the 0 crossing, and re-observation must
    // never double-decrement.
    for (spec, sub, _) in backends() {
        let state = sub.state;
        let n = 12i64;
        assert!(state.init_counter("deps:child", n));
        assert!(!state.init_counter("deps:child", 99));
        let mut handles = Vec::new();
        for p in 0..n {
            for _dup in 0..3 {
                let state = state.clone();
                handles.push(std::thread::spawn(move || {
                    state.edge_decr(&format!("edge:{p}:child"), "deps:child") == 0
                }));
            }
        }
        let zeros: usize = handles
            .into_iter()
            .map(|h| h.join().unwrap() as usize)
            .sum();
        assert!(zeros >= 1, "[{spec}] someone must observe the 0 crossing");
        assert_eq!(state.counter("deps:child"), 0, "[{spec}] exact count");
        // Post-hoc re-execution still observes 0, still no drift.
        assert_eq!(state.edge_decr("edge:0:child", "deps:child"), 0);
        assert_eq!(state.counter("deps:child"), 0);
    }
}

#[test]
fn kv_counter_sum_exact_under_contention() {
    for (spec, sub, _) in backends() {
        let state = sub.state;
        let threads = 8;
        let per = 200;
        let mut handles = Vec::new();
        for _ in 0..threads {
            let state = state.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..per {
                    state.incr("hot", 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(state.counter("hot"), (threads * per) as i64, "[{spec}]");
        assert!(state.op_count() >= (threads * per) as u64, "[{spec}]");
    }
}

// ---------- Queue ----------

#[test]
fn queue_lease_expiry_redelivers_and_rejects_stale() {
    for (spec, sub, clock) in backends() {
        let q = sub.queue;
        q.send("t", 0);
        assert_eq!(q.len(), 1);
        assert_eq!(q.visible_len(), 1);
        let (_, lease1) = q.receive().unwrap();
        assert!(q.receive().is_none(), "[{spec}] invisible while leased");
        assert_eq!(q.visible_len(), 0, "[{spec}]");
        clock.advance(LEASE + Duration::from_secs(1));
        // Lease expired → visible again (at-least-once).
        let (_, lease2) = q.receive().unwrap();
        assert_eq!(q.delivery_count("t"), 2, "[{spec}]");
        // Stale lease can neither renew nor delete.
        assert!(!q.renew(&lease1), "[{spec}]");
        assert!(!q.delete(&lease1), "[{spec}]");
        // Fresh lease works.
        assert!(q.renew(&lease2), "[{spec}]");
        assert!(q.delete(&lease2), "[{spec}]");
        assert!(q.is_empty(), "[{spec}]");
    }
}

#[test]
fn queue_renewal_keeps_invisible() {
    for (spec, sub, clock) in backends() {
        let q = sub.queue;
        q.send("t", 0);
        let (_, lease) = q.receive().unwrap();
        clock.advance(Duration::from_secs(8));
        assert!(q.renew(&lease), "[{spec}]");
        clock.advance(Duration::from_secs(8));
        // 16s since receive but renewed at 8s → still invisible.
        assert!(q.receive().is_none(), "[{spec}]");
        clock.advance(Duration::from_secs(3));
        assert!(q.receive().is_some(), "[{spec}] expired after renewal lapsed");
    }
}

#[test]
fn queue_lease_expiry_invariant_under_clock_skew() {
    // ROADMAP item 3's satellite, pinned as a regression test: the
    // substrate's clock may disagree with the workers' by a constant
    // offset (`chaos(skew=…)`), and lease-expiry redelivery — the
    // whole §4.1 at-least-once protocol — must be *invariant* under
    // it, because the queue stamps leases and checks expiry through
    // the same skewed handle. The observable delivery trace must be
    // identical at zero, large-positive, and large-negative skew.
    let trace = |spec: &str| -> Vec<(u32, bool, bool)> {
        let clock = Arc::new(TestClock::default());
        let cfg = SubstrateConfig::parse(spec).unwrap();
        let sub = Substrate::build_with_clock(&cfg, LEASE, Duration::ZERO, clock.clone());
        // Start well past the epoch so a negative offset never
        // saturates (a real wall clock is never near its epoch).
        clock.advance(Duration::from_secs(60));
        let q = sub.queue;
        let mut out = Vec::new();
        q.send("t", 0);
        let (_, lease1) = q.receive().unwrap();
        out.push((q.delivery_count("t"), q.receive().is_none(), q.renew(&lease1)));
        // Half a lease: renewed above, so still invisible.
        clock.advance(LEASE / 2 + Duration::from_secs(1));
        out.push((q.delivery_count("t"), q.receive().is_none(), q.renew(&lease1)));
        // Past the renewed lease: redelivered, stale lease rejected.
        clock.advance(LEASE + Duration::from_secs(1));
        let (_, lease2) = q.receive().unwrap();
        out.push((q.delivery_count("t"), q.renew(&lease1), q.delete(&lease1)));
        out.push((q.delivery_count("t"), q.renew(&lease2), q.delete(&lease2)));
        out.push((q.delivery_count("t"), q.is_empty(), true));
        out
    };
    let baseline = trace("strict");
    for spec in [
        "strict+chaos(skew=5s,seed=1)",
        "strict+chaos(skew=-5s,seed=1)",
        "sharded:1+chaos(skew=5s,seed=1)",
        "sharded:1+chaos(skew=-5s,seed=1)",
        "file:auto+chaos(skew=5s,seed=1)",
        "file:auto+chaos(skew=-5s,seed=1)",
    ] {
        assert_eq!(trace(spec), baseline, "[{spec}] skew changed lease behavior");
    }
    // And the clause really reaches the queue: near the epoch a
    // negative offset *does* saturate, visibly stretching the first
    // lease (take stamped at the clamped origin) — proof the skewed
    // lens, not the fleet clock, is what the backend reads.
    let clock = Arc::new(TestClock::default());
    let cfg = SubstrateConfig::parse("strict+chaos(skew=-5s,seed=1)").unwrap();
    let sub = Substrate::build_with_clock(&cfg, LEASE, Duration::ZERO, clock.clone());
    let q = sub.queue;
    q.send("t", 0);
    let (_, _lease) = q.receive().unwrap();
    clock.advance(LEASE + Duration::from_secs(1));
    assert!(
        q.receive().is_none(),
        "saturated skewed clock has only advanced 6s of the 10s lease"
    );
    clock.advance(Duration::from_secs(9));
    assert!(q.receive().is_some(), "expires once the skewed clock catches up");
}

#[test]
fn queue_concurrent_receivers_no_loss_no_duplication() {
    for (spec, sub, _) in backends() {
        let q = sub.queue;
        let total = 96;
        for i in 0..total {
            q.send(&format!("m{i}"), (i % 5) as i64);
        }
        let mut handles = Vec::new();
        for _ in 0..8 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some((body, lease)) = q.receive() {
                    got.push(body);
                    assert!(q.delete(&lease));
                }
                got
            }));
        }
        let mut all: Vec<String> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), total, "[{spec}] exactly-once while leases held");
        assert!(q.is_empty(), "[{spec}]");
    }
}

#[test]
fn queue_fifo_within_priority_deterministic() {
    // The critical-path satellite: same-priority messages (tasks from
    // the same program line) must pop in enqueue order, not arbitrary
    // heap order. Pinned on the globally-ordered backends.
    for (spec, sub, _) in ordered_backends() {
        let q = sub.queue;
        for i in 0..20 {
            q.send(&format!("line2-{i}"), -2);
        }
        q.send("line0", 0);
        q.send("line1", -1);
        assert_eq!(q.receive().unwrap().0, "line0", "[{spec}] priority first");
        assert_eq!(q.receive().unwrap().0, "line1", "[{spec}]");
        for i in 0..20 {
            let (body, lease) = q.receive().unwrap();
            assert_eq!(body, format!("line2-{i}"), "[{spec}] FIFO within priority");
            q.delete(&lease);
        }
    }
}

#[test]
fn queue_blocking_receive_sees_cross_thread_send() {
    for (spec, sub, _) in backends() {
        let q = sub.queue;
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.receive_timeout(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(20));
        q.send("x", 0);
        assert_eq!(h.join().unwrap().unwrap().0, "x", "[{spec}]");
        assert!(
            q.receive_timeout(Duration::from_millis(20)).is_none(),
            "[{spec}] times out empty"
        );
    }
}

// ---------- BlobStore ----------

#[test]
fn blob_read_after_write_and_accounting() {
    for (spec, sub, _) in backends() {
        let blob = sub.blob;
        let mut handles = Vec::new();
        for t in 0..8usize {
            let blob = blob.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..16 {
                    let key = format!("T[{t},{i}]");
                    let m = Matrix::from_vec(1, 2, vec![t as f64, i as f64]);
                    blob.put(t, &key, m).unwrap();
                    let got = blob.get(t, &key).unwrap();
                    assert_eq!(got[(0, 1)], i as f64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(blob.len(), 8 * 16, "[{spec}]");
        assert!(blob.contains("T[0,0]"), "[{spec}]");
        assert!(!blob.contains("T[9,9]"), "[{spec}]");
        assert!(blob.get(0, "T[9,9]").is_err(), "[{spec}]");
        let stats = blob.stats();
        // 1×2 f64 tiles = 16 bytes each way per op. Writes always
        // reach the substrate (write-through); reads only do on a
        // cache miss, and write-allocate makes every read-back after
        // a same-worker put a local hit — the whole point of the
        // locality layer is that `bytes_read` drops to zero here.
        assert_eq!(stats.put_ops, 8 * 16, "[{spec}]");
        assert_eq!(stats.bytes_written, 8 * 16 * 16, "[{spec}]");
        if spec.contains("+cache") {
            assert_eq!(stats.get_ops, 0, "[{spec}] all reads served locally");
            assert_eq!(stats.bytes_read, 0, "[{spec}]");
        } else {
            assert_eq!(stats.get_ops, 8 * 16, "[{spec}]");
            assert_eq!(stats.bytes_read, 8 * 16 * 16, "[{spec}]");
        }
        assert_eq!(blob.known_workers().len(), 8, "[{spec}]");
        assert_eq!(blob.worker_stats(3).put_ops, 16, "[{spec}]");
        assert_eq!(blob.worker_stats(99).put_ops, 0, "[{spec}]");
    }
}

// ---------- Lifecycle ops (delete / scan / prefix sweeps) ----------

#[test]
fn blob_delete_scan_delete_prefix_contract() {
    for (spec, sub, _) in backends() {
        let blob = sub.blob;
        for (ns, k) in [("j1", 0), ("j1", 1), ("j1", 2), ("j2", 0)] {
            blob.put(0, &format!("{ns}/T[{k}]"), Matrix::zeros(1, 1)).unwrap();
        }
        blob.put(0, "j1/O[0]", Matrix::eye(2)).unwrap();
        // scan: sorted, prefix-scoped, empty on a miss.
        let j1 = blob.scan_prefix("j1/");
        assert_eq!(j1.len(), 4, "[{spec}]");
        assert!(j1.windows(2).all(|w| w[0] < w[1]), "[{spec}] sorted");
        assert!(j1.iter().all(|k| k.starts_with("j1/")), "[{spec}]");
        assert_eq!(blob.scan_prefix("j9/").len(), 0, "[{spec}]");
        assert_eq!(blob.scan_prefix("").len(), 5, "[{spec}] empty prefix = all");
        // single-key delete: true once, then a no-op.
        assert!(blob.delete("j1/T[0]").unwrap(), "[{spec}]");
        assert!(!blob.delete("j1/T[0]").unwrap(), "[{spec}]");
        assert!(!blob.contains("j1/T[0]"), "[{spec}]");
        assert!(blob.get(0, "j1/T[0]").is_err(), "[{spec}] read-after-delete");
        // prefix sweep returns the exact reclamation count.
        assert_eq!(blob.delete_prefix("j1/"), 3, "[{spec}]");
        assert_eq!(blob.delete_prefix("j1/"), 0, "[{spec}] idempotent");
        assert_eq!(blob.len(), 1, "[{spec}] other namespaces intact");
        assert!(blob.contains("j2/T[0]"), "[{spec}]");
    }
}

#[test]
fn blob_prefix_age_contract() {
    // The TTL sweeper's age signal: `None` for an empty namespace,
    // monotone-growing while write-idle, refreshed only by writes
    // (reads must not rejuvenate), scoped to the prefix.
    for (spec, sub, _) in backends() {
        let blob = sub.blob;
        assert_eq!(blob.prefix_age("j1/"), None, "[{spec}] empty = ageless");
        blob.put(0, "j1/T[0]", Matrix::zeros(1, 1)).unwrap();
        blob.put(0, "j2/T[0]", Matrix::zeros(1, 1)).unwrap();
        std::thread::sleep(Duration::from_millis(15));
        let aged = blob.prefix_age("j1/").unwrap();
        assert!(aged >= Duration::from_millis(15), "[{spec}] {aged:?}");
        blob.get(0, "j1/T[0]").unwrap();
        assert!(
            blob.prefix_age("j1/").unwrap() >= Duration::from_millis(15),
            "[{spec}] a read must not refresh the age"
        );
        // A write anywhere under the prefix rejuvenates it; the
        // neighbor namespace keeps its own clock.
        blob.put(0, "j1/T[1]", Matrix::zeros(1, 1)).unwrap();
        assert!(blob.prefix_age("j1/").unwrap() < aged, "[{spec}]");
        assert!(
            blob.prefix_age("j2/").unwrap() >= Duration::from_millis(15),
            "[{spec}] neighbor unaffected"
        );
        // The one-scan bulk form agrees with per-prefix queries:
        // sorted, grouped by the delimiter, same ages.
        let ages = blob.prefix_ages('/');
        let names: Vec<&str> = ages.iter().map(|(p, _)| p.as_str()).collect();
        assert_eq!(names, vec!["j1/", "j2/"], "[{spec}]");
        for (prefix, age) in &ages {
            let single = blob.prefix_age(prefix).unwrap();
            let diff = single.abs_diff(*age);
            assert!(diff < Duration::from_millis(50), "[{spec}] {prefix}: {single:?} vs {age:?}");
        }
        // Deleting the namespace forgets its age entirely.
        blob.delete_prefix("j1/");
        assert_eq!(blob.prefix_age("j1/"), None, "[{spec}]");
        assert_eq!(blob.prefix_ages('/').len(), 1, "[{spec}] j2 remains");
    }
}

#[test]
fn cache_invalidation_tracks_gc_sweeps() {
    // Retention / TTL sweeps reclaim whole namespaces through the same
    // decorated `Arc<dyn BlobStore>` handle the workers read through;
    // a worker cache surviving the sweep would resurrect deleted
    // tiles. Pin invalidate-on-lifecycle-op end-to-end.
    let cfg = SubstrateConfig::parse("sharded:4+cache(bytes=4m)").unwrap();
    let sub = Substrate::build_with_clock(
        &cfg,
        LEASE,
        Duration::ZERO,
        Arc::new(TestClock::default()),
    );
    let blob = sub.blob.clone();
    let cache = sub.cache.clone().expect("+cache spec populates the handle");
    blob.put(0, "j1/T[0]", Matrix::from_vec(1, 1, vec![1.0])).unwrap();
    blob.put(0, "j1/T[1]", Matrix::from_vec(1, 1, vec![2.0])).unwrap();
    blob.put(1, "j2/T[0]", Matrix::from_vec(1, 1, vec![3.0])).unwrap();
    // Warm worker 0's cache, then sweep j1 the way job GC does.
    assert_eq!(blob.get(0, "j1/T[0]").unwrap()[(0, 0)], 1.0);
    assert_eq!(cache.cache_stats().hits, 1, "write-allocate primes the cache");
    assert_eq!(blob.delete_prefix("j1/"), 2);
    assert!(blob.get(0, "j1/T[0]").is_err(), "swept tile served from cache");
    assert!(blob.get(0, "j1/T[1]").is_err());
    // The neighbor namespace's cached tile is untouched.
    assert_eq!(blob.get(1, "j2/T[0]").unwrap()[(0, 0)], 3.0);
    // Single-key delete invalidates every worker's cache, not just the
    // writer's.
    blob.put(0, "j1/T[0]", Matrix::from_vec(1, 1, vec![4.0])).unwrap();
    assert_eq!(blob.get(1, "j1/T[0]").unwrap()[(0, 0)], 4.0);
    assert!(blob.delete("j1/T[0]").unwrap());
    assert!(blob.get(1, "j1/T[0]").is_err(), "cross-worker invalidation");
    // Re-put after the delete serves the new tile, never the ghost.
    blob.put(2, "j1/T[0]", Matrix::from_vec(1, 1, vec![5.0])).unwrap();
    assert_eq!(blob.get(0, "j1/T[0]").unwrap()[(0, 0)], 5.0);
    assert_eq!(blob.get(1, "j1/T[0]").unwrap()[(0, 0)], 5.0);
    let stats = cache.cache_stats();
    assert!(stats.invalidations >= 3, "{stats:?}");
}

#[test]
fn kv_delete_scan_delete_prefix_contract() {
    for (spec, sub, _) in backends() {
        let state = sub.state;
        // One job's worth of control state: status (string KV), deps
        // counter, edge guards (counter space), plus a neighbor job.
        state.set("j1/status:a", "completed");
        state.init_counter("j1/deps:b", 2);
        state.edge_decr("j1/edge:a:b", "j1/deps:b");
        state.incr("j1/completed_total", 1);
        state.set("j2/status:a", "pending");
        state.init_counter("j2/deps:b", 1);
        let j1 = state.scan_prefix("j1/");
        assert_eq!(j1.len(), 4, "[{spec}] {j1:?}");
        assert!(j1.windows(2).all(|w| w[0] < w[1]), "[{spec}] sorted");
        // delete spans both the string KV and the counter space.
        assert!(state.delete("j1/status:a"), "[{spec}]");
        assert!(!state.delete("j1/status:a"), "[{spec}]");
        assert!(state.delete("j1/deps:b"), "[{spec}] counter deleted");
        assert!(!state.counter_exists("j1/deps:b"), "[{spec}]");
        assert_eq!(
            state.delete_prefix("j1/"),
            2,
            "[{spec}] edge guard + completed counter"
        );
        assert_eq!(state.delete_prefix("j1/"), 0, "[{spec}] idempotent");
        // The neighbor job is untouched.
        assert_eq!(state.get("j2/status:a").as_deref(), Some("pending"), "[{spec}]");
        assert_eq!(state.counter("j2/deps:b"), 1, "[{spec}]");
        // Deleted counters re-initialize from scratch (no ghost state).
        assert!(state.init_counter("j1/deps:b", 7), "[{spec}]");
        assert_eq!(state.counter("j1/deps:b"), 7, "[{spec}]");
    }
}

#[test]
fn queue_purge_prefix_contract() {
    for (spec, sub, _) in backends() {
        let q = sub.queue;
        for i in 0..8 {
            q.send(&format!("1|t{i}"), 0);
            q.send(&format!("2|t{i}"), 0);
        }
        // Lease one job-1 message (priority boost pins which one on the
        // ordered backends; on sharded it may be any — both fine).
        q.send("1|urgent", 100);
        let (got, lease) = q.receive().unwrap();
        assert_eq!(q.len(), 17, "[{spec}]");
        let purged = q.purge_prefix("1|");
        assert_eq!(purged, 9, "[{spec}] visible + leased all purged");
        assert_eq!(q.len(), 8, "[{spec}]");
        if got.starts_with("1|") {
            assert!(!q.delete(&lease), "[{spec}] purged lease is stale");
            assert!(!q.renew(&lease), "[{spec}]");
        } else {
            assert!(q.delete(&lease), "[{spec}] untouched lease stays valid");
        }
        // Remaining messages all belong to job 2 and still flow.
        let mut drained = 0;
        while let Some((body, l)) = q.receive() {
            assert!(body.starts_with("2|"), "[{spec}] got {body}");
            assert!(q.delete(&l), "[{spec}]");
            drained += 1;
        }
        assert!(drained >= 7, "[{spec}] {drained}");
        assert_eq!(q.purge_prefix("2|"), 0, "[{spec}] nothing left");
        assert!(q.is_empty(), "[{spec}]");
    }
}

// ---------- End-to-end ----------

#[test]
fn engine_cholesky_correct_on_every_backend() {
    // The chaos specs are the acceptance bar for the decorator layer:
    // transient blob faults (`err>0`) recovered by worker retries and
    // lease redelivery must still produce exact numerics.
    for spec in [
        "strict",
        "sharded:4",
        "sharded:auto",
        "sharded:4+chaos(err=0.02,lat=fixed:50us,seed=11)",
        "strict+chaos(drop=0.05,dup=0.05,seed=13)",
        "sharded:4+chaos(send_lat=uniform:10us:100us,seed=17)",
        // The locality layer in full: LRU tile cache + chain-import
        // prefetch + hinted claiming, with and without chaos under it.
        "sharded:4+cache(bytes=8m)",
        "sharded:4+cache(bytes=8388608)+chaos(err=0.02,lat=fixed:50us,seed=11)",
        // The file family end-to-end: every tile, counter, and lease
        // on disk, bare and under each decorator (the ISSUE acceptance
        // triple: file, file+chaos, file+cache).
        "file:auto",
        "file:auto+chaos(err=0.02,lat=fixed:50us,seed=11)",
        "file:auto+cache(bytes=8m)",
    ] {
        let mut rng = Rng::new(17);
        let a = Matrix::rand_spd(24, &mut rng);
        let cfg = EngineConfig {
            scaling: ScalingMode::Fixed(4),
            job_timeout: Duration::from_secs(120),
            substrate: SubstrateConfig::parse(spec).unwrap(),
            ..EngineConfig::default()
        };
        let out = drivers::cholesky(&Engine::new(cfg), &a, 8).unwrap();
        assert!(
            out.result.matmul_nt(&out.result).max_abs_diff(&a) < 1e-8,
            "[{spec}] LLᵀ ≠ A"
        );
        let r = &out.run.report;
        assert_eq!(r.completed, r.total_tasks, "[{spec}]");
        assert!(r.error.is_none(), "[{spec}]");
    }
}

#[test]
fn engine_recovers_from_heavy_chaos_faults() {
    // err=0.3 defeats the inline retry budget often enough that some
    // tasks are abandoned to lease-expiry recovery — the full §4.1
    // path (stop renewing → visibility timeout → redelivery →
    // idempotent re-execution) on the real engine. The cache leg pins
    // that redelivered tasks re-reading through warm worker caches
    // still land on exact numerics: invalidation-on-delete plus SSA
    // writes mean a cached tile is never stale.
    for spec in [
        "sharded:4+chaos(err=0.3,seed=23)",
        "sharded:4+cache(bytes=8m)+chaos(err=0.3,seed=23)",
        "file:auto+chaos(err=0.3,seed=23)",
    ] {
        let mut rng = Rng::new(19);
        let a = Matrix::rand_spd(24, &mut rng);
        let cfg = EngineConfig {
            scaling: ScalingMode::Fixed(6),
            lease: Duration::from_millis(80),
            job_timeout: Duration::from_secs(120),
            substrate: SubstrateConfig::parse(spec).unwrap(),
            ..EngineConfig::default()
        };
        let out = drivers::cholesky(&Engine::new(cfg), &a, 8).unwrap();
        assert!(
            out.result.matmul_nt(&out.result).max_abs_diff(&a) < 1e-8,
            "[{spec}] LLᵀ ≠ A"
        );
        let r = &out.run.report;
        assert_eq!(r.completed, r.total_tasks, "[{spec}]");
        assert!(r.error.is_none(), "[{spec}]");
        assert_eq!(r.cache.is_some(), spec.contains("+cache"), "[{spec}]");
    }
}

#[test]
fn engine_short_lease_stragglers_safe_on_sharded() {
    // Redelivery + duplicate execution under the sharded backend:
    // idempotence must hold exactly as it does on strict.
    let mut rng = Rng::new(18);
    let a = Matrix::rand_spd(24, &mut rng);
    let cfg = EngineConfig {
        scaling: ScalingMode::Fixed(6),
        lease: Duration::from_millis(20),
        store_latency: Duration::from_millis(8),
        job_timeout: Duration::from_secs(120),
        substrate: SubstrateConfig::parse("sharded:8").unwrap(),
        ..EngineConfig::default()
    };
    let out = drivers::cholesky(&Engine::new(cfg), &a, 8).unwrap();
    assert!(out.result.matmul_nt(&out.result).max_abs_diff(&a) < 1e-8);
    let r = &out.run.report;
    assert_eq!(r.completed, r.total_tasks);
}

#[test]
fn engine_chaos_stragglers_slow_but_exact() {
    // Worker-visible blob-store slowdowns: a deterministic fraction of
    // workers see multiplied store latency (the straggler experiment);
    // the schedule degrades, the numerics must not.
    let mut rng = Rng::new(21);
    let a = Matrix::rand_spd(24, &mut rng);
    let cfg = EngineConfig {
        scaling: ScalingMode::Fixed(4),
        job_timeout: Duration::from_secs(120),
        substrate: SubstrateConfig::parse(
            "sharded:4+chaos(lat=uniform:50us:200us,straggle=0.5:8,seed=29)",
        )
        .unwrap(),
        ..EngineConfig::default()
    };
    let out = drivers::cholesky(&Engine::new(cfg), &a, 8).unwrap();
    assert!(out.result.matmul_nt(&out.result).max_abs_diff(&a) < 1e-8);
    assert_eq!(out.run.report.completed, out.run.report.total_tasks);
}
