//! A vendored, offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment for this repository carries no registry
//! crates, so the workspace depends on this shim by path under the
//! same crate name. It implements exactly the surface the codebase
//! uses:
//!
//! * [`Error`] — an opaque error with a context chain;
//! * [`Result<T>`] — alias defaulting the error type;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on both
//!   `Result` and `Option`;
//! * [`anyhow!`] / [`bail!`] — format-style constructors;
//! * `{e}` prints the outermost message, `{e:#}` the whole chain
//!   colon-separated, `{e:?}` a multi-line report — matching the real
//!   crate's formatting contract closely enough for tests that assert
//!   on substrings.
//!
//! Deliberately not implemented (unused here): downcasting, backtrace
//! capture, `ensure!`, `Error::new`/`chain` accessors.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error with an ordered chain of context messages. The most
/// recently attached context is the outermost message.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Error {
            msg: msg.to_string(),
            cause: None,
        }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: context.to_string(),
            cause: Some(Box::new(self)),
        }
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>, sep: &str) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.cause.as_deref();
        while let Some(e) = cur {
            write!(f, "{sep}{}", e.msg)?;
            cur = e.cause.as_deref();
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{e:#}` — the full chain, outermost first.
            self.write_chain(f, ": ")
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.cause.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = self.cause.as_deref();
            let mut i = 0;
            while let Some(e) = cur {
                write!(f, "\n    {i}: {}", e.msg)?;
                cur = e.cause.as_deref();
                i += 1;
            }
        }
        Ok(())
    }
}

// `Error` intentionally does NOT implement `std::error::Error`: that
// is what keeps this blanket conversion coherent alongside the
// identity `From<Error> for Error`, exactly as in the real crate.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain: Vec<String> = Vec::new();
        chain.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            err = Some(Error {
                msg,
                cause: err.map(Box::new),
            });
        }
        err.expect("chain has at least one entry")
    }
}

/// Context attachment for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i64> {
        let n: i64 = s.parse().context("not an integer")?;
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("41").unwrap(), 41);
        let e = parse("x").unwrap_err();
        assert_eq!(format!("{e}"), "not an integer");
        let full = format!("{e:#}");
        assert!(full.starts_with("not an integer: "), "{full}");
    }

    #[test]
    fn context_chain_orders_outermost_first() {
        let base: Error = anyhow!("inner");
        let e = Err::<(), Error>(base)
            .context("middle")
            .with_context(|| format!("line {}", 2))
            .unwrap_err();
        assert_eq!(format!("{e}"), "line 2");
        assert_eq!(format!("{e:#}"), "line 2: middle: inner");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.with_context(|| "missing --flag").unwrap_err();
        assert_eq!(format!("{e:#}"), "missing --flag");
        assert_eq!(Some(3u8).context("present").unwrap(), 3);
    }

    #[test]
    fn bail_returns_formatted() {
        fn f(x: i64) -> Result<i64> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(format!("{}", f(-2).unwrap_err()), "negative: -2");
        assert_eq!(f(5).unwrap(), 5);
    }
}
